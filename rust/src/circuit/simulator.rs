//! Bit-parallel logic simulation.
//!
//! Evaluation packs 64 test vectors into each `u64` word (lane *i* of every
//! word belongs to vector *i*), and the gate sweep is additionally
//! *lane-blocked*: each pass over the gate list evaluates a block of
//! [`LANE_BLOCK`] packed words (256 vectors) at once. Per-signal state is a
//! `[u64; LANE_BLOCK]` so the netlist — and every gate node — is walked 4×
//! less often per vector, the four lane words of a gate evaluate as
//! independent unrolled chains, and the packing/unpacking boundary is
//! amortised over the whole block. This is the workhorse that makes
//! exhaustive evaluation of 8×8-bit multipliers (2¹⁶ vectors) cheap enough
//! for the CGP inner loop.
//!
//! Two evaluation modes mirror the paper (§II-C):
//! * **exhaustive** — all `2^n_inputs` vectors, used up to
//!   [`MAX_EXHAUSTIVE_INPUTS`] primary inputs;
//! * **sampled** — caller-supplied vectors (the library uses deterministic
//!   stratified samples for wide adders/multipliers where the paper defers
//!   to SAT/BDD-based analysis); interfaces beyond 64 inputs/outputs go
//!   through the multi-word variant ([`BitSim::eval_vectors_wide`], up to
//!   [`MAX_IO_BITS`] = 256 bits — a 128×128-bit multiplier).
//!
//! A [`BitSim`] owns all of its buffers — signal words, packed input/output
//! words and the result vector — and reuses them across calls, so repeated
//! evaluation (library characterisation, LUT building, verification sweeps)
//! performs no per-call heap allocation beyond initial growth. The one-shot
//! helpers at the bottom route through a per-thread shared instance for the
//! same reason.
//!
//! The same sweep also collects per-signal ones-densities, from which the
//! cost model derives zero-delay switching activities for dynamic power.

use std::cell::RefCell;

use super::netlist::Netlist;
use super::wide::U256;

/// Exhaustive evaluation is permitted up to this many primary inputs
/// (2²⁰ ≈ 1 M vectors; an 8×8 multiplier needs 2¹⁶).
pub const MAX_EXHAUSTIVE_INPUTS: u32 = 20;

/// Widest primary-input/-output interface of the multi-word sampled path:
/// four packed words = 256 bits, enough for a 128×128-bit multiplier
/// (256 inputs, 256 outputs). The bit-parallel sweep itself is
/// width-agnostic — one lane block per *signal* — so only vector
/// packing/unpacking is multi-word.
pub const MAX_IO_BITS: u32 = 256;

/// Packed 64-lane words evaluated per gate-list sweep (4 words = 256
/// vectors per pass over the netlist).
pub const LANE_BLOCK: usize = 4;

/// Vectors evaluated per gate-list sweep.
const BLOCK_LANES: usize = LANE_BLOCK * 64;

/// Lane patterns for exhaustive enumeration: input `i < 6` toggles with
/// period `2^i` inside every 64-lane word.
const LOW_INPUT_PATTERNS: [u64; 6] = [
    0xAAAA_AAAA_AAAA_AAAA, // i=0: 0101...
    0xCCCC_CCCC_CCCC_CCCC, // i=1
    0xF0F0_F0F0_F0F0_F0F0, // i=2
    0xFF00_FF00_FF00_FF00, // i=3
    0xFFFF_0000_FFFF_0000, // i=4
    0xFFFF_FFFF_0000_0000, // i=5
];

/// Word that primary input `i` contributes to word-index `w` of the
/// exhaustive enumeration (vectors `64w .. 64w+63`).
#[inline(always)]
pub fn exhaustive_input_word(i: u32, w: u64) -> u64 {
    if i < 6 {
        LOW_INPUT_PATTERNS[i as usize]
    } else if (w >> (i - 6)) & 1 == 1 {
        !0
    } else {
        0
    }
}

/// Validity masks for the first `m` lanes of a block (`0 < m <=`
/// [`BLOCK_LANES`]).
#[inline]
fn valid_masks(m: usize) -> [u64; LANE_BLOCK] {
    let mut v = [0u64; LANE_BLOCK];
    for (wi, slot) in v.iter_mut().enumerate() {
        let lanes = m.saturating_sub(wi * 64).min(64);
        *slot = if lanes == 64 { !0 } else { (1u64 << lanes) - 1 };
    }
    v
}

/// Reusable simulation scratch: per-signal lane blocks plus packed
/// input/output words and the result buffer, all retained across calls.
/// Keeping it allocated across candidate evaluations removes allocation
/// from the characterisation and LUT-building hot loops.
#[derive(Debug, Default)]
pub struct BitSim {
    sig: Vec<[u64; LANE_BLOCK]>,
    /// per-signal count of one-lanes accumulated over `n_vectors`.
    ones: Vec<u64>,
    n_vectors: u64,
    track_activity: bool,
    in_words: Vec<[u64; LANE_BLOCK]>,
    out_words: Vec<[u64; LANE_BLOCK]>,
    result: Vec<u64>,
    result_wide: Vec<U256>,
}

impl BitSim {
    /// New simulator; `track_activity` additionally accumulates per-signal
    /// ones counts (used by the power model, skipped in the CGP hot loop).
    pub fn new(track_activity: bool) -> Self {
        BitSim {
            track_activity,
            ..Default::default()
        }
    }

    fn reset(&mut self, n: &Netlist) {
        self.sig.clear();
        self.sig.resize(n.n_signals() as usize, [0; LANE_BLOCK]);
        if self.track_activity {
            self.ones.clear();
            self.ones.resize(n.n_signals() as usize, 0);
        }
        self.n_vectors = 0;
        self.in_words.clear();
        self.in_words.resize(n.n_inputs as usize, [0; LANE_BLOCK]);
        self.out_words.clear();
        self.out_words.resize(n.outputs.len(), [0; LANE_BLOCK]);
    }

    /// Evaluate one lane block: `in_words[i]` holds the packed words for
    /// primary input `i`, `out_words[j]` receives primary output `j`.
    /// `valid` masks how many lanes of each word are real vectors.
    fn eval_block(&mut self, n: &Netlist, valid: &[u64; LANE_BLOCK]) {
        let ni = n.n_inputs as usize;
        let BitSim {
            sig,
            ones,
            n_vectors,
            track_activity,
            in_words,
            out_words,
            ..
        } = self;
        sig[..ni].copy_from_slice(&in_words[..ni]);
        // Single forward sweep — nodes are topologically ordered by
        // construction. The four words of a gate are independent chains.
        let (in_sigs, gate_sigs) = sig.split_at_mut(ni);
        for (g, node) in n.nodes.iter().enumerate() {
            let a = if (node.a as usize) < ni {
                in_sigs[node.a as usize]
            } else {
                gate_sigs[node.a as usize - ni]
            };
            let b = if (node.b as usize) < ni {
                in_sigs[node.b as usize]
            } else {
                gate_sigs[node.b as usize - ni]
            };
            let k = node.kind;
            gate_sigs[g] = [
                k.eval_word(a[0], b[0]),
                k.eval_word(a[1], b[1]),
                k.eval_word(a[2], b[2]),
                k.eval_word(a[3], b[3]),
            ];
        }
        for (ow, &o) in out_words.iter_mut().zip(n.outputs.iter()) {
            let s = sig[o as usize];
            *ow = [
                s[0] & valid[0],
                s[1] & valid[1],
                s[2] & valid[2],
                s[3] & valid[3],
            ];
        }
        if *track_activity {
            for (acc, w) in ones.iter_mut().zip(sig.iter()) {
                *acc += (w[0] & valid[0]).count_ones() as u64
                    + (w[1] & valid[1]).count_ones() as u64
                    + (w[2] & valid[2]).count_ones() as u64
                    + (w[3] & valid[3]).count_ones() as u64;
            }
            *n_vectors += valid.iter().map(|v| v.count_ones() as u64).sum::<u64>();
        }
    }

    /// Unpack the first `m` lanes of the current output block into
    /// `result[base..base+m]` (outputs packed little-endian per vector).
    fn unpack_block(&mut self, base: usize, m: usize) {
        let out = &mut self.result[base..base + m];
        for (lane, slot) in out.iter_mut().enumerate() {
            let (wi, li) = (lane / 64, lane % 64);
            let mut val = 0u64;
            for (j, ow) in self.out_words.iter().enumerate() {
                val |= ((ow[wi] >> li) & 1) << j;
            }
            *slot = val;
        }
    }

    /// Exhaustive evaluation: returns the output value (outputs packed
    /// little-endian into a `u64`) for every input index `0..2^n_inputs`.
    /// The slice borrows this simulator's reusable result buffer.
    pub fn eval_exhaustive(&mut self, n: &Netlist) -> &[u64] {
        assert!(
            n.n_inputs <= MAX_EXHAUSTIVE_INPUTS,
            "{} inputs exceeds exhaustive limit {MAX_EXHAUSTIVE_INPUTS}; use sampled evaluation",
            n.n_inputs
        );
        assert!(n.outputs.len() <= 64, "more than 64 outputs");
        self.reset(n);
        let n_vec: u64 = 1u64 << n.n_inputs;
        self.result.clear();
        self.result.resize(n_vec as usize, 0);
        let mut base = 0u64;
        while base < n_vec {
            let m = (n_vec - base).min(BLOCK_LANES as u64) as usize;
            let w0 = base / 64;
            for i in 0..n.n_inputs {
                self.in_words[i as usize] = [
                    exhaustive_input_word(i, w0),
                    exhaustive_input_word(i, w0 + 1),
                    exhaustive_input_word(i, w0 + 2),
                    exhaustive_input_word(i, w0 + 3),
                ];
            }
            self.eval_block(n, &valid_masks(m));
            self.unpack_block(base as usize, m);
            base += m as u64;
        }
        &self.result
    }

    /// Sampled evaluation: `vectors[k]` packs the primary-input values of
    /// sample `k` (bit `i` = input `i`). Returns one output value per
    /// sample, borrowed from the reusable result buffer.
    pub fn eval_vectors(&mut self, n: &Netlist, vectors: &[u64]) -> &[u64] {
        assert!(
            n.n_inputs <= 64,
            "more than 64 inputs — use eval_vectors_wide"
        );
        assert!(
            n.outputs.len() <= 64,
            "more than 64 outputs — use eval_vectors_wide"
        );
        self.reset(n);
        self.result.clear();
        self.result.resize(vectors.len(), 0);
        for (blk, chunk) in vectors.chunks(BLOCK_LANES).enumerate() {
            for w in self.in_words.iter_mut() {
                *w = [0; LANE_BLOCK];
            }
            for (lane, &v) in chunk.iter().enumerate() {
                let (wi, li) = (lane / 64, lane % 64);
                for (i, w) in self.in_words.iter_mut().enumerate() {
                    w[wi] |= ((v >> i) & 1) << li;
                }
            }
            self.eval_block(n, &valid_masks(chunk.len()));
            self.unpack_block(blk * BLOCK_LANES, chunk.len());
        }
        &self.result
    }

    /// Multi-word sampled evaluation for wide interfaces: `vectors[k]`
    /// packs up to [`MAX_IO_BITS`] primary-input bits of sample `k`
    /// (bit `i` = input `i`); returns one packed output value per sample,
    /// borrowed from the reusable wide result buffer. This is the path
    /// that removes the 64-input/64-output cliff of
    /// [`BitSim::eval_vectors`] — same lane-blocked forward sweep,
    /// multi-word lane packing at the boundary.
    pub fn eval_vectors_wide(&mut self, n: &Netlist, vectors: &[U256]) -> &[U256] {
        assert!(n.n_inputs <= MAX_IO_BITS, "more than {MAX_IO_BITS} inputs");
        assert!(
            n.outputs.len() <= MAX_IO_BITS as usize,
            "more than {MAX_IO_BITS} outputs"
        );
        self.reset(n);
        self.result_wide.clear();
        self.result_wide.resize(vectors.len(), U256::ZERO);
        for (blk, chunk) in vectors.chunks(BLOCK_LANES).enumerate() {
            for w in self.in_words.iter_mut() {
                *w = [0; LANE_BLOCK];
            }
            for (lane, &v) in chunk.iter().enumerate() {
                let (wi, li) = (lane / 64, lane % 64);
                let vw = v.words();
                for (i, w) in self.in_words.iter_mut().enumerate() {
                    w[wi] |= ((vw[i >> 6] >> (i & 63)) & 1) << li;
                }
            }
            self.eval_block(n, &valid_masks(chunk.len()));
            let base = blk * BLOCK_LANES;
            let out = &mut self.result_wide[base..base + chunk.len()];
            for (lane, slot) in out.iter_mut().enumerate() {
                let (wi, li) = (lane / 64, lane % 64);
                let mut val = U256::ZERO;
                for (j, ow) in self.out_words.iter().enumerate() {
                    val.or_bit(j as u32, (ow[wi] >> li) & 1);
                }
                *slot = val;
            }
        }
        &self.result_wide
    }

    /// Per-signal ones-density `p` after an activity-tracked run, from which
    /// the zero-delay switching activity is `α = 2·p·(1−p)`.
    pub fn activity(&self) -> Activity {
        assert!(self.track_activity, "simulator built without activity tracking");
        let nv = self.n_vectors.max(1) as f64;
        Activity {
            ones_frac: self.ones.iter().map(|&o| o as f64 / nv).collect(),
            n_vectors: self.n_vectors,
        }
    }
}

/// Per-signal ones-densities from a simulation run.
#[derive(Debug, Clone)]
pub struct Activity {
    /// Fraction of evaluated vectors on which each signal was 1.
    pub ones_frac: Vec<f64>,
    /// Number of vectors the densities were estimated over.
    pub n_vectors: u64,
}

impl Activity {
    /// Zero-delay switching activity of signal `s`: `2·p·(1−p)` — the
    /// probability that two independent consecutive vectors toggle it.
    pub fn switching(&self, s: usize) -> f64 {
        let p = self.ones_frac[s];
        2.0 * p * (1.0 - p)
    }
}

thread_local! {
    /// Per-thread simulator shared by the one-shot helpers below, so
    /// repeated helper calls (library ingestion, LUT building, sweeps)
    /// reuse grown buffers instead of allocating a fresh `BitSim` each
    /// time.
    static SHARED: RefCell<BitSim> = RefCell::new(BitSim::new(false));
    /// Activity-tracking twin of [`SHARED`].
    static SHARED_ACTIVITY: RefCell<BitSim> = RefCell::new(BitSim::new(true));
}

/// Run `f` against this thread's shared (non-activity) simulator: borrow
/// evaluation results without copying them out of the scratch buffer.
pub fn with_shared_sim<R>(f: impl FnOnce(&mut BitSim) -> R) -> R {
    SHARED.with(|s| f(&mut s.borrow_mut()))
}

/// One-shot exhaustive evaluation (convenience wrapper over the shared
/// per-thread simulator; use [`with_shared_sim`] to avoid the copy-out).
pub fn eval_exhaustive_u64(n: &Netlist) -> Vec<u64> {
    SHARED.with(|s| s.borrow_mut().eval_exhaustive(n).to_vec())
}

/// One-shot sampled evaluation.
pub fn eval_vectors_u64(n: &Netlist, vectors: &[u64]) -> Vec<u64> {
    SHARED.with(|s| s.borrow_mut().eval_vectors(n, vectors).to_vec())
}

/// One-shot multi-word sampled evaluation (wide interfaces).
pub fn eval_vectors_wide(n: &Netlist, vectors: &[U256]) -> Vec<U256> {
    SHARED.with(|s| s.borrow_mut().eval_vectors_wide(n, vectors).to_vec())
}

/// Multi-word sampled evaluation with activity collection (wide power
/// estimation path).
pub fn activity_vectors_wide(n: &Netlist, vectors: &[U256]) -> (Vec<U256>, Activity) {
    SHARED_ACTIVITY.with(|s| {
        let mut sim = s.borrow_mut();
        let table = sim.eval_vectors_wide(n, vectors).to_vec();
        let act = sim.activity();
        (table, act)
    })
}

/// Exhaustive evaluation with activity collection (power estimation path).
pub fn activity_exhaustive(n: &Netlist) -> (Vec<u64>, Activity) {
    SHARED_ACTIVITY.with(|s| {
        let mut sim = s.borrow_mut();
        let table = sim.eval_exhaustive(n).to_vec();
        let act = sim.activity();
        (table, act)
    })
}

/// Sampled evaluation with activity collection.
pub fn activity_vectors(n: &Netlist, vectors: &[u64]) -> (Vec<u64>, Activity) {
    SHARED_ACTIVITY.with(|s| {
        let mut sim = s.borrow_mut();
        let table = sim.eval_vectors(n, vectors).to_vec();
        let act = sim.activity();
        (table, act)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::gate::GateKind;

    fn xor2() -> Netlist {
        let mut n = Netlist::new(2, "xor2");
        let g = n.push(GateKind::Xor, 0, 1);
        n.output(g);
        n
    }

    fn par7() -> Netlist {
        let mut n = Netlist::new(7, "par7");
        let mut acc = n.input(0);
        for i in 1..7 {
            acc = n.push(GateKind::Xor, acc, i);
        }
        n.output(acc);
        n
    }

    #[test]
    fn exhaustive_xor() {
        assert_eq!(eval_exhaustive_u64(&xor2()), vec![0, 1, 1, 0]);
    }

    #[test]
    fn sampled_matches_exhaustive() {
        let n = xor2();
        let vecs: Vec<u64> = (0..4).collect();
        assert_eq!(eval_vectors_u64(&n, &vecs), eval_exhaustive_u64(&n));
    }

    #[test]
    fn sampled_partial_word_and_multiword() {
        // 7-input parity circuit, 300 samples (crosses word boundaries AND
        // the 256-lane block boundary, ending mid-word).
        let n = par7();
        let vecs: Vec<u64> = (0..300).map(|k| (k * 37) % 128).collect();
        let got = eval_vectors_u64(&n, &vecs);
        for (k, &v) in vecs.iter().enumerate() {
            assert_eq!(got[k], (v.count_ones() as u64) & 1, "sample {k}");
        }
    }

    #[test]
    fn exhaustive_input_patterns_enumerate_all_vectors() {
        // inputs reproduced as outputs must enumerate 0..2^n in order;
        // 8 inputs = 256 vectors = exactly one lane block.
        let mut n = Netlist::new(8, "id8");
        for i in 0..8 {
            n.output(i);
        }
        let t = eval_exhaustive_u64(&n);
        assert_eq!(t.len(), 256);
        for (i, &v) in t.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn exhaustive_multi_block_enumerates_in_order() {
        // 10 inputs = 1024 vectors = four full lane blocks.
        let mut n = Netlist::new(10, "id10");
        for i in 0..10 {
            n.output(i);
        }
        let t = eval_exhaustive_u64(&n);
        assert_eq!(t.len(), 1024);
        for (i, &v) in t.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn scratch_reuse_across_circuits_and_modes() {
        // One simulator instance driven through shrinking/growing circuits
        // and all three modes — stale buffer contents must never leak.
        let mut sim = BitSim::new(false);
        let x = xor2();
        let n = par7();
        assert_eq!(sim.eval_exhaustive(&x).to_vec(), vec![0, 1, 1, 0]);
        let vecs: Vec<u64> = (0..300).map(|k| (k * 37) % 128).collect();
        let got = sim.eval_vectors(&n, &vecs).to_vec();
        for (k, &v) in vecs.iter().enumerate() {
            assert_eq!(got[k], (v.count_ones() as u64) & 1, "sample {k}");
        }
        // back to the small circuit on the same (now larger) buffers
        assert_eq!(sim.eval_exhaustive(&x).to_vec(), vec![0, 1, 1, 0]);
        let wide_vecs: Vec<U256> = vecs.iter().map(|&v| U256::from_u64(v)).collect();
        let wide = sim.eval_vectors_wide(&n, &wide_vecs).to_vec();
        for (k, &v) in vecs.iter().enumerate() {
            assert_eq!(wide[k], U256::from_u64((v.count_ones() as u64) & 1));
        }
    }

    #[test]
    fn activity_densities() {
        let n = xor2();
        let (_, act) = activity_exhaustive(&n);
        // inputs are balanced, xor of two balanced independent inputs is balanced
        assert_eq!(act.n_vectors, 4);
        assert!((act.ones_frac[0] - 0.5).abs() < 1e-12);
        assert!((act.ones_frac[2] - 0.5).abs() < 1e-12);
        assert!((act.switching(2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn const_gate_activity_is_zero() {
        let mut n = Netlist::new(1, "c");
        let z = n.push(GateKind::Const0, 0, 0);
        let o = n.push(GateKind::Const1, 0, 0);
        n.output(z);
        n.output(o);
        let (t, act) = activity_exhaustive(&n);
        assert_eq!(t, vec![0b10, 0b10]);
        assert_eq!(act.ones_frac[1], 0.0);
        assert_eq!(act.ones_frac[2], 1.0);
        assert_eq!(act.switching(1), 0.0);
        assert_eq!(act.switching(2), 0.0);
    }

    #[test]
    #[should_panic(expected = "exhaustive limit")]
    fn exhaustive_limit_enforced() {
        let n = Netlist::new(24, "wide");
        eval_exhaustive_u64(&n);
    }

    #[test]
    fn wide_identity_200_inputs_echoes_vectors() {
        // 200 inputs / 200 outputs — far past the old 64-bit cliff.
        let mut n = Netlist::new(200, "id200");
        for i in 0..200 {
            n.output(i);
        }
        let mut vecs = Vec::new();
        for k in 0..300u32 {
            let mut v = U256::ZERO;
            // deterministic sparse pattern touching every word
            for bit in [k % 200, (k * 37) % 200, (k * 71 + 199) % 200] {
                v.or_bit(bit, 1);
            }
            vecs.push(v);
        }
        let got = eval_vectors_wide(&n, &vecs);
        assert_eq!(got, vecs, "identity must echo all 200 bits per lane");
    }

    #[test]
    fn wide_matches_narrow_on_narrow_circuits() {
        // 7-input parity, 300 samples (crosses word and block boundaries,
        // ends mid-word): the wide path must agree bit-for-bit with
        // eval_vectors.
        let n = par7();
        let narrow_vecs: Vec<u64> = (0..300).map(|k| (k * 37) % 128).collect();
        let wide_vecs: Vec<U256> = narrow_vecs.iter().map(|&v| U256::from_u64(v)).collect();
        let narrow = eval_vectors_u64(&n, &narrow_vecs);
        let wide = eval_vectors_wide(&n, &wide_vecs);
        assert_eq!(narrow.len(), wide.len());
        for (a, b) in narrow.iter().zip(&wide) {
            assert_eq!(U256::from_u64(*a), *b);
        }
    }

    #[test]
    fn wide_activity_matches_narrow_activity() {
        let n = xor2();
        let vecs: Vec<u64> = (0..4).collect();
        let (_, narrow) = activity_vectors(&n, &vecs);
        let wide_vecs: Vec<U256> = vecs.iter().map(|&v| U256::from_u64(v)).collect();
        let (_, wide) = activity_vectors_wide(&n, &wide_vecs);
        assert_eq!(narrow.n_vectors, wide.n_vectors);
        assert_eq!(narrow.ones_frac, wide.ones_frac);
    }

    #[test]
    #[should_panic(expected = "eval_vectors_wide")]
    fn narrow_sampled_path_rejects_wide_interfaces() {
        let n = Netlist::new(65, "toowide");
        eval_vectors_u64(&n, &[0]);
    }
}
