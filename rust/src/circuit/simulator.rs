//! Bit-parallel logic simulation.
//!
//! Evaluation packs 64 test vectors into each `u64` word (lane *i* of every
//! word belongs to vector *i*), so one sweep over the gate list evaluates 64
//! input vectors at once — the workhorse that makes exhaustive evaluation of
//! 8×8-bit multipliers (2¹⁶ vectors) cheap enough for the CGP inner loop.
//!
//! Two evaluation modes mirror the paper (§II-C):
//! * **exhaustive** — all `2^n_inputs` vectors, used up to
//!   [`MAX_EXHAUSTIVE_INPUTS`] primary inputs;
//! * **sampled** — caller-supplied vectors (the library uses deterministic
//!   stratified samples for wide adders/multipliers where the paper defers
//!   to SAT/BDD-based analysis); interfaces beyond 64 inputs/outputs go
//!   through the multi-word variant ([`BitSim::eval_vectors_wide`], up to
//!   [`MAX_IO_BITS`] = 256 bits — a 128×128-bit multiplier).
//!
//! The same sweep also collects per-signal ones-densities, from which the
//! cost model derives zero-delay switching activities for dynamic power.

use super::netlist::Netlist;
use super::wide::U256;

/// Exhaustive evaluation is permitted up to this many primary inputs
/// (2²⁰ ≈ 1 M vectors; an 8×8 multiplier needs 2¹⁶).
pub const MAX_EXHAUSTIVE_INPUTS: u32 = 20;

/// Widest primary-input/-output interface of the multi-word sampled path:
/// four packed words = 256 bits, enough for a 128×128-bit multiplier
/// (256 inputs, 256 outputs). The bit-parallel sweep itself is
/// width-agnostic — one 64-lane word per *signal* — so only vector
/// packing/unpacking is multi-word.
pub const MAX_IO_BITS: u32 = 256;

/// Lane patterns for exhaustive enumeration: input `i < 6` toggles with
/// period `2^i` inside every 64-lane word.
const LOW_INPUT_PATTERNS: [u64; 6] = [
    0xAAAA_AAAA_AAAA_AAAA, // i=0: 0101...
    0xCCCC_CCCC_CCCC_CCCC, // i=1
    0xF0F0_F0F0_F0F0_F0F0, // i=2
    0xFF00_FF00_FF00_FF00, // i=3
    0xFFFF_0000_FFFF_0000, // i=4
    0xFFFF_FFFF_0000_0000, // i=5
];

/// Word that primary input `i` contributes to word-index `w` of the
/// exhaustive enumeration (vectors `64w .. 64w+63`).
#[inline(always)]
pub fn exhaustive_input_word(i: u32, w: u64) -> u64 {
    if i < 6 {
        LOW_INPUT_PATTERNS[i as usize]
    } else if (w >> (i - 6)) & 1 == 1 {
        !0
    } else {
        0
    }
}

/// Reusable simulation scratch (signal values for one 64-vector word).
/// Keeping it allocated across candidate evaluations removes allocation from
/// the CGP hot loop.
#[derive(Debug, Default)]
pub struct BitSim {
    sig: Vec<u64>,
    /// per-signal count of one-lanes accumulated over `n_vectors`.
    ones: Vec<u64>,
    n_vectors: u64,
    track_activity: bool,
}

impl BitSim {
    /// New simulator; `track_activity` additionally accumulates per-signal
    /// ones counts (used by the power model, skipped in the CGP hot loop).
    pub fn new(track_activity: bool) -> Self {
        BitSim {
            sig: Vec::new(),
            ones: Vec::new(),
            n_vectors: 0,
            track_activity,
        }
    }

    fn reset(&mut self, n: &Netlist) {
        self.sig.clear();
        self.sig.resize(n.n_signals() as usize, 0);
        if self.track_activity {
            self.ones.clear();
            self.ones.resize(n.n_signals() as usize, 0);
        }
        self.n_vectors = 0;
    }

    /// Evaluate one packed word: `inputs[i]` is the 64-lane word for primary
    /// input `i`; `out[j]` receives the word for primary output `j`.
    /// `valid_lanes` masks how many of the 64 lanes are real vectors.
    #[inline]
    fn eval_word_into(&mut self, n: &Netlist, inputs: &[u64], valid_lanes: u64, out: &mut [u64]) {
        let ni = n.n_inputs as usize;
        self.sig[..ni].copy_from_slice(inputs);
        // Single forward sweep — nodes are topologically ordered by
        // construction.
        let (in_sigs, gate_sigs) = self.sig.split_at_mut(ni);
        for (g, node) in n.nodes.iter().enumerate() {
            let a = if (node.a as usize) < ni {
                in_sigs[node.a as usize]
            } else {
                gate_sigs[node.a as usize - ni]
            };
            let b = if (node.b as usize) < ni {
                in_sigs[node.b as usize]
            } else {
                gate_sigs[node.b as usize - ni]
            };
            gate_sigs[g] = node.kind.eval_word(a, b);
        }
        for (j, &o) in n.outputs.iter().enumerate() {
            out[j] = self.sig[o as usize] & valid_lanes;
        }
        if self.track_activity {
            for (s, &w) in self.sig.iter().enumerate() {
                self.ones[s] += (w & valid_lanes).count_ones() as u64;
            }
            self.n_vectors += valid_lanes.count_ones() as u64;
        }
    }

    /// Exhaustive evaluation: returns the output value (outputs packed
    /// little-endian into a `u64`) for every input index `0..2^n_inputs`.
    pub fn eval_exhaustive(&mut self, n: &Netlist) -> Vec<u64> {
        assert!(
            n.n_inputs <= MAX_EXHAUSTIVE_INPUTS,
            "{} inputs exceeds exhaustive limit {MAX_EXHAUSTIVE_INPUTS}; use sampled evaluation",
            n.n_inputs
        );
        assert!(n.outputs.len() <= 64, "more than 64 outputs");
        self.reset(n);
        let n_vec: u64 = 1u64 << n.n_inputs;
        let n_words = n_vec.div_ceil(64);
        let valid = if n_vec >= 64 { !0u64 } else { (1u64 << n_vec) - 1 };
        let mut result = vec![0u64; n_vec as usize];
        let mut in_words = vec![0u64; n.n_inputs as usize];
        let mut out_words = vec![0u64; n.outputs.len()];
        for w in 0..n_words {
            for i in 0..n.n_inputs {
                in_words[i as usize] = exhaustive_input_word(i, w);
            }
            self.eval_word_into(n, &in_words, valid, &mut out_words);
            unpack_outputs(&out_words, w, n_vec, &mut result);
        }
        result
    }

    /// Sampled evaluation: `vectors[k]` packs the primary-input values of
    /// sample `k` (bit `i` = input `i`). Returns one output value per sample.
    pub fn eval_vectors(&mut self, n: &Netlist, vectors: &[u64]) -> Vec<u64> {
        assert!(
            n.n_inputs <= 64,
            "more than 64 inputs — use eval_vectors_wide"
        );
        assert!(
            n.outputs.len() <= 64,
            "more than 64 outputs — use eval_vectors_wide"
        );
        self.reset(n);
        let mut result = vec![0u64; vectors.len()];
        let mut in_words = vec![0u64; n.n_inputs as usize];
        let mut out_words = vec![0u64; n.outputs.len()];
        for (w, chunk) in vectors.chunks(64).enumerate() {
            in_words.iter_mut().for_each(|x| *x = 0);
            for (lane, &v) in chunk.iter().enumerate() {
                for i in 0..n.n_inputs as usize {
                    in_words[i] |= ((v >> i) & 1) << lane;
                }
            }
            let valid = if chunk.len() == 64 {
                !0u64
            } else {
                (1u64 << chunk.len()) - 1
            };
            self.eval_word_into(n, &in_words, valid, &mut out_words);
            for (lane, slot) in chunk.iter().enumerate().map(|(l, _)| l).zip(
                result[w * 64..w * 64 + chunk.len()].iter_mut(),
            ) {
                let mut val = 0u64;
                for (j, &ow) in out_words.iter().enumerate() {
                    val |= ((ow >> lane) & 1) << j;
                }
                *slot = val;
            }
        }
        result
    }

    /// Multi-word sampled evaluation for wide interfaces: `vectors[k]`
    /// packs up to [`MAX_IO_BITS`] primary-input bits of sample `k`
    /// (bit `i` = input `i`); returns one packed output value per sample.
    /// This is the path that removes the 64-input/64-output cliff of
    /// [`BitSim::eval_vectors`] — same single forward sweep, multi-word
    /// lane packing at the boundary.
    pub fn eval_vectors_wide(&mut self, n: &Netlist, vectors: &[U256]) -> Vec<U256> {
        assert!(n.n_inputs <= MAX_IO_BITS, "more than {MAX_IO_BITS} inputs");
        assert!(
            n.outputs.len() <= MAX_IO_BITS as usize,
            "more than {MAX_IO_BITS} outputs"
        );
        self.reset(n);
        let mut result = vec![U256::ZERO; vectors.len()];
        let mut in_words = vec![0u64; n.n_inputs as usize];
        let mut out_words = vec![0u64; n.outputs.len()];
        for (wi, chunk) in vectors.chunks(64).enumerate() {
            in_words.iter_mut().for_each(|x| *x = 0);
            for (lane, &v) in chunk.iter().enumerate() {
                for i in 0..n.n_inputs {
                    in_words[i as usize] |= v.bit(i) << lane;
                }
            }
            let valid = if chunk.len() == 64 {
                !0u64
            } else {
                (1u64 << chunk.len()) - 1
            };
            self.eval_word_into(n, &in_words, valid, &mut out_words);
            for (lane, slot) in result[wi * 64..wi * 64 + chunk.len()]
                .iter_mut()
                .enumerate()
            {
                let mut val = U256::ZERO;
                for (j, &ow) in out_words.iter().enumerate() {
                    val.or_bit(j as u32, (ow >> lane) & 1);
                }
                *slot = val;
            }
        }
        result
    }

    /// Per-signal ones-density `p` after an activity-tracked run, from which
    /// the zero-delay switching activity is `α = 2·p·(1−p)`.
    pub fn activity(&self) -> Activity {
        assert!(self.track_activity, "simulator built without activity tracking");
        let nv = self.n_vectors.max(1) as f64;
        Activity {
            ones_frac: self.ones.iter().map(|&o| o as f64 / nv).collect(),
            n_vectors: self.n_vectors,
        }
    }
}

/// Per-signal ones-densities from a simulation run.
#[derive(Debug, Clone)]
pub struct Activity {
    /// Fraction of evaluated vectors on which each signal was 1.
    pub ones_frac: Vec<f64>,
    /// Number of vectors the densities were estimated over.
    pub n_vectors: u64,
}

impl Activity {
    /// Zero-delay switching activity of signal `s`: `2·p·(1−p)` — the
    /// probability that two independent consecutive vectors toggle it.
    pub fn switching(&self, s: usize) -> f64 {
        let p = self.ones_frac[s];
        2.0 * p * (1.0 - p)
    }
}

#[inline]
fn unpack_outputs(out_words: &[u64], w: u64, n_vec: u64, result: &mut [u64]) {
    let base = w * 64;
    let lanes = (n_vec - base).min(64);
    for lane in 0..lanes {
        let mut val = 0u64;
        for (j, &ow) in out_words.iter().enumerate() {
            val |= ((ow >> lane) & 1) << j;
        }
        result[(base + lane) as usize] = val;
    }
}

/// One-shot exhaustive evaluation (convenience wrapper; tests and
/// LUT-building use this, the CGP loop reuses a [`BitSim`]).
pub fn eval_exhaustive_u64(n: &Netlist) -> Vec<u64> {
    BitSim::new(false).eval_exhaustive(n)
}

/// One-shot sampled evaluation.
pub fn eval_vectors_u64(n: &Netlist, vectors: &[u64]) -> Vec<u64> {
    BitSim::new(false).eval_vectors(n, vectors)
}

/// One-shot multi-word sampled evaluation (wide interfaces).
pub fn eval_vectors_wide(n: &Netlist, vectors: &[U256]) -> Vec<U256> {
    BitSim::new(false).eval_vectors_wide(n, vectors)
}

/// Multi-word sampled evaluation with activity collection (wide power
/// estimation path).
pub fn activity_vectors_wide(n: &Netlist, vectors: &[U256]) -> (Vec<U256>, Activity) {
    let mut sim = BitSim::new(true);
    let table = sim.eval_vectors_wide(n, vectors);
    let act = sim.activity();
    (table, act)
}

/// Exhaustive evaluation with activity collection (power estimation path).
pub fn activity_exhaustive(n: &Netlist) -> (Vec<u64>, Activity) {
    let mut sim = BitSim::new(true);
    let table = sim.eval_exhaustive(n);
    let act = sim.activity();
    (table, act)
}

/// Sampled evaluation with activity collection.
pub fn activity_vectors(n: &Netlist, vectors: &[u64]) -> (Vec<u64>, Activity) {
    let mut sim = BitSim::new(true);
    let table = sim.eval_vectors(n, vectors);
    let act = sim.activity();
    (table, act)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::gate::GateKind;

    fn xor2() -> Netlist {
        let mut n = Netlist::new(2, "xor2");
        let g = n.push(GateKind::Xor, 0, 1);
        n.output(g);
        n
    }

    #[test]
    fn exhaustive_xor() {
        assert_eq!(eval_exhaustive_u64(&xor2()), vec![0, 1, 1, 0]);
    }

    #[test]
    fn sampled_matches_exhaustive() {
        let n = xor2();
        let vecs: Vec<u64> = (0..4).collect();
        assert_eq!(eval_vectors_u64(&n, &vecs), eval_exhaustive_u64(&n));
    }

    #[test]
    fn sampled_partial_word_and_multiword() {
        // 7-input parity circuit, 130 samples (crosses a word boundary and
        // ends mid-word).
        let mut n = Netlist::new(7, "par7");
        let mut acc = n.input(0);
        for i in 1..7 {
            acc = n.push(GateKind::Xor, acc, i);
        }
        n.output(acc);
        let vecs: Vec<u64> = (0..130).map(|k| (k * 37) % 128).collect();
        let got = eval_vectors_u64(&n, &vecs);
        for (k, &v) in vecs.iter().enumerate() {
            assert_eq!(got[k], (v.count_ones() as u64) & 1, "sample {k}");
        }
    }

    #[test]
    fn exhaustive_input_patterns_enumerate_all_vectors() {
        // inputs reproduced as outputs must enumerate 0..2^n in order
        let mut n = Netlist::new(8, "id8");
        for i in 0..8 {
            n.output(i);
        }
        let t = eval_exhaustive_u64(&n);
        assert_eq!(t.len(), 256);
        for (i, &v) in t.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn activity_densities() {
        let n = xor2();
        let (_, act) = activity_exhaustive(&n);
        // inputs are balanced, xor of two balanced independent inputs is balanced
        assert_eq!(act.n_vectors, 4);
        assert!((act.ones_frac[0] - 0.5).abs() < 1e-12);
        assert!((act.ones_frac[2] - 0.5).abs() < 1e-12);
        assert!((act.switching(2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn const_gate_activity_is_zero() {
        let mut n = Netlist::new(1, "c");
        let z = n.push(GateKind::Const0, 0, 0);
        let o = n.push(GateKind::Const1, 0, 0);
        n.output(z);
        n.output(o);
        let (t, act) = activity_exhaustive(&n);
        assert_eq!(t, vec![0b10, 0b10]);
        assert_eq!(act.ones_frac[1], 0.0);
        assert_eq!(act.ones_frac[2], 1.0);
        assert_eq!(act.switching(1), 0.0);
        assert_eq!(act.switching(2), 0.0);
    }

    #[test]
    #[should_panic(expected = "exhaustive limit")]
    fn exhaustive_limit_enforced() {
        let n = Netlist::new(24, "wide");
        eval_exhaustive_u64(&n);
    }

    #[test]
    fn wide_identity_200_inputs_echoes_vectors() {
        // 200 inputs / 200 outputs — far past the old 64-bit cliff.
        let mut n = Netlist::new(200, "id200");
        for i in 0..200 {
            n.output(i);
        }
        let mut vecs = Vec::new();
        for k in 0..130u32 {
            let mut v = U256::ZERO;
            // deterministic sparse pattern touching every word
            for bit in [k % 200, (k * 37) % 200, (k * 71 + 199) % 200] {
                v.or_bit(bit, 1);
            }
            vecs.push(v);
        }
        let got = eval_vectors_wide(&n, &vecs);
        assert_eq!(got, vecs, "identity must echo all 200 bits per lane");
    }

    #[test]
    fn wide_matches_narrow_on_narrow_circuits() {
        // 7-input parity, 130 samples (crosses a word boundary and ends
        // mid-word): the wide path must agree bit-for-bit with eval_vectors.
        let mut n = Netlist::new(7, "par7");
        let mut acc = n.input(0);
        for i in 1..7 {
            acc = n.push(GateKind::Xor, acc, i);
        }
        n.output(acc);
        let narrow_vecs: Vec<u64> = (0..130).map(|k| (k * 37) % 128).collect();
        let wide_vecs: Vec<U256> = narrow_vecs.iter().map(|&v| U256::from_u64(v)).collect();
        let narrow = eval_vectors_u64(&n, &narrow_vecs);
        let wide = eval_vectors_wide(&n, &wide_vecs);
        assert_eq!(narrow.len(), wide.len());
        for (a, b) in narrow.iter().zip(&wide) {
            assert_eq!(U256::from_u64(*a), *b);
        }
    }

    #[test]
    fn wide_activity_matches_narrow_activity() {
        let n = xor2();
        let vecs: Vec<u64> = (0..4).collect();
        let (_, narrow) = activity_vectors(&n, &vecs);
        let wide_vecs: Vec<U256> = vecs.iter().map(|&v| U256::from_u64(v)).collect();
        let (_, wide) = activity_vectors_wide(&n, &wide_vecs);
        assert_eq!(narrow.n_vectors, wide.n_vectors);
        assert_eq!(narrow.ones_frac, wide.ones_frac);
    }

    #[test]
    #[should_panic(expected = "eval_vectors_wide")]
    fn narrow_sampled_path_rejects_wide_interfaces() {
        let n = Netlist::new(65, "toowide");
        eval_vectors_u64(&n, &[0]);
    }
}
