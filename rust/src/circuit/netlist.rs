//! Gate-level netlist: the circuit representation shared by the generators,
//! the CGP decoder, the simulator and the cost model.
//!
//! A [`Netlist`] is a DAG over *signals*. Signal ids are assigned densely:
//! `0..n_inputs` are the primary inputs, every added gate creates the next
//! id. Outputs are an ordered list of signal ids. Nodes are stored in
//! topological order by construction (a gate may only reference
//! already-existing signals), which makes simulation a single forward sweep.

use std::collections::HashMap;


use super::gate::GateKind;

/// Id of a signal (primary input or gate output) within a netlist.
pub type SignalId = u32;

/// One gate instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Node {
    /// Gate function.
    pub kind: GateKind,
    /// First input signal.
    pub a: SignalId,
    /// Second input signal (ignored by arity-<2 gates but always valid).
    pub b: SignalId,
}

/// A combinational circuit as a topologically ordered gate list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Netlist {
    /// Number of primary inputs.
    pub n_inputs: u32,
    /// Gates; gate `g` drives signal `n_inputs + g`.
    pub nodes: Vec<Node>,
    /// Primary outputs (ordered, may repeat or reference inputs directly).
    pub outputs: Vec<SignalId>,
    /// Human-readable name, e.g. `mul8u_wallace` or `mul8u_evo_a3f2`.
    pub name: String,
}

impl Netlist {
    /// Create an empty netlist with `n_inputs` primary inputs.
    pub fn new(n_inputs: u32, name: impl Into<String>) -> Self {
        Netlist {
            n_inputs,
            nodes: Vec::new(),
            outputs: Vec::new(),
            name: name.into(),
        }
    }

    /// Total number of signals (inputs + gate outputs).
    #[inline]
    pub fn n_signals(&self) -> u32 {
        self.n_inputs + self.nodes.len() as u32
    }

    /// Number of primary outputs.
    #[inline]
    pub fn n_outputs(&self) -> u32 {
        self.outputs.len() as u32
    }

    /// Signal id of primary input `i`.
    #[inline]
    pub fn input(&self, i: u32) -> SignalId {
        debug_assert!(i < self.n_inputs);
        i
    }

    /// Append a gate; returns the signal it drives. Panics if an operand
    /// references a not-yet-existing signal (would break topological order).
    pub fn push(&mut self, kind: GateKind, a: SignalId, b: SignalId) -> SignalId {
        let id = self.n_signals();
        assert!(a < id && b < id, "operand references future signal");
        self.nodes.push(Node { kind, a, b });
        id
    }

    /// Convenience unary gate.
    pub fn push1(&mut self, kind: GateKind, a: SignalId) -> SignalId {
        self.push(kind, a, a)
    }

    /// Constant-0 signal.
    pub fn zero(&mut self) -> SignalId {
        self.push(GateKind::Const0, 0.min(self.n_signals() - 1), 0)
    }

    /// Constant-1 signal.
    pub fn one(&mut self) -> SignalId {
        self.push(GateKind::Const1, 0.min(self.n_signals() - 1), 0)
    }

    /// Mark a signal as the next primary output.
    pub fn output(&mut self, s: SignalId) {
        assert!(s < self.n_signals(), "output references unknown signal");
        self.outputs.push(s);
    }

    /// Ids of gates that are *active*, i.e. in the transitive fan-in of some
    /// primary output. CGP chromosomes routinely contain inactive nodes; cost
    /// is always charged on active gates only (as in the paper's fitness).
    pub fn active_gates(&self) -> Vec<bool> {
        let n = self.nodes.len();
        let mut active = vec![false; n];
        let mut stack: Vec<SignalId> = self
            .outputs
            .iter()
            .copied()
            .filter(|&s| s >= self.n_inputs)
            .collect();
        while let Some(s) = stack.pop() {
            let g = (s - self.n_inputs) as usize;
            if active[g] {
                continue;
            }
            active[g] = true;
            let node = &self.nodes[g];
            let arity = node.kind.arity();
            if arity >= 1 && node.a >= self.n_inputs {
                stack.push(node.a);
            }
            if arity >= 2 && node.b >= self.n_inputs {
                stack.push(node.b);
            }
        }
        active
    }

    /// Number of active gates, excluding zero-cost buffers/constants
    /// (the paper's "number of gates" objective counts logic gates).
    pub fn active_gate_count(&self) -> usize {
        let active = self.active_gates();
        self.nodes
            .iter()
            .zip(active)
            .filter(|(n, a)| {
                *a && !matches!(
                    n.kind,
                    GateKind::Identity | GateKind::Const0 | GateKind::Const1
                )
            })
            .count()
    }

    /// Produce a compacted copy containing only active gates (dead gates and
    /// their wiring removed, signal ids renumbered). Output order preserved.
    pub fn compact(&self) -> Netlist {
        let active = self.active_gates();
        let mut remap: HashMap<SignalId, SignalId> = HashMap::new();
        for i in 0..self.n_inputs {
            remap.insert(i, i);
        }
        let mut out = Netlist::new(self.n_inputs, self.name.clone());
        for (g, node) in self.nodes.iter().enumerate() {
            if !active[g] {
                continue;
            }
            // Unused operand slots (arity < 2) may point at dead gates that
            // were not remapped; tie them to input 0 instead.
            let arity = node.kind.arity();
            let a = if arity >= 1 {
                *remap.get(&node.a).expect("active fan-in must be remapped")
            } else {
                0
            };
            let b = if arity >= 2 {
                *remap.get(&node.b).expect("active fan-in must be remapped")
            } else {
                a
            };
            let new_id = out.push(node.kind, a, b);
            remap.insert(self.n_inputs + g as u32, new_id);
        }
        for &o in &self.outputs {
            let mapped = *remap
                .get(&o)
                .expect("active output must have been remapped");
            out.output(mapped);
        }
        out
    }

    /// Logic depth (longest input→output path counting logic gates only).
    pub fn depth(&self) -> u32 {
        let mut depth = vec![0u32; self.n_signals() as usize];
        for (g, node) in self.nodes.iter().enumerate() {
            let id = (self.n_inputs as usize) + g;
            let d = match node.kind.arity() {
                0 => 0,
                1 => depth[node.a as usize],
                _ => depth[node.a as usize].max(depth[node.b as usize]),
            };
            let cost = matches!(
                node.kind,
                GateKind::Identity | GateKind::Const0 | GateKind::Const1
            ) as u32;
            depth[id] = d + (1 - cost);
        }
        self.outputs
            .iter()
            .map(|&o| depth[o as usize])
            .max()
            .unwrap_or(0)
    }

    /// Structural sanity check: all operand/out references in range and
    /// topologically ordered. Returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        for (g, node) in self.nodes.iter().enumerate() {
            let id = self.n_inputs + g as u32;
            if node.a >= id || node.b >= id {
                return Err(format!("gate {g} references future signal"));
            }
        }
        for (i, &o) in self.outputs.iter().enumerate() {
            if o >= self.n_signals() {
                return Err(format!("output {i} references unknown signal {o}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::simulator::eval_exhaustive_u64;

    /// Build a 1-bit full adder and check its truth table.
    #[test]
    fn full_adder() {
        let mut n = Netlist::new(3, "fa");
        let (a, b, cin) = (0, 1, 2);
        let axb = n.push(GateKind::Xor, a, b);
        let sum = n.push(GateKind::Xor, axb, cin);
        let ab = n.push(GateKind::And, a, b);
        let cx = n.push(GateKind::And, axb, cin);
        let cout = n.push(GateKind::Or, ab, cx);
        n.output(sum);
        n.output(cout);
        assert!(n.validate().is_ok());
        let table = eval_exhaustive_u64(&n);
        for i in 0u64..8 {
            let (a, b, c) = (i & 1, (i >> 1) & 1, (i >> 2) & 1);
            let expect = a + b + c;
            assert_eq!(table[i as usize], expect, "a={a} b={b} cin={c}");
        }
    }

    #[test]
    fn active_gate_extraction() {
        let mut n = Netlist::new(2, "t");
        let g0 = n.push(GateKind::And, 0, 1);
        let _dead = n.push(GateKind::Or, 0, 1);
        let g2 = n.push(GateKind::Xor, g0, 0);
        n.output(g2);
        let active = n.active_gates();
        assert_eq!(active, vec![true, false, true]);
        assert_eq!(n.active_gate_count(), 2);
        let compacted = n.compact();
        assert_eq!(compacted.nodes.len(), 2);
        assert_eq!(
            eval_exhaustive_u64(&n),
            eval_exhaustive_u64(&compacted),
            "compaction must preserve function"
        );
    }

    #[test]
    fn depth_ignores_buffers() {
        let mut n = Netlist::new(2, "d");
        let g0 = n.push(GateKind::And, 0, 1);
        let b = n.push1(GateKind::Identity, g0);
        let g1 = n.push(GateKind::Xor, b, 0);
        n.output(g1);
        assert_eq!(n.depth(), 2);
    }

    #[test]
    #[should_panic(expected = "future signal")]
    fn rejects_forward_reference() {
        let mut n = Netlist::new(1, "bad");
        n.push(GateKind::And, 0, 5);
    }

    #[test]
    fn output_can_be_input_passthrough() {
        let mut n = Netlist::new(2, "wire");
        n.output(1);
        n.output(0);
        let t = eval_exhaustive_u64(&n);
        // out0 = in1, out1 = in0 → value = in1 | in0<<1
        assert_eq!(t, vec![0b00, 0b10, 0b01, 0b11]);
    }
}
