//! Model-guided search over heterogeneous per-layer assignments.
//!
//! The assignment space is `choices^layers` (choice 0 = the exact
//! multiplier, choice `c ≥ 1` = library candidate `c-1` in that layer) —
//! far too large to evaluate on the real backend. Both objectives are
//! *separable per layer* under the probe-fitted model:
//!
//! * predicted accuracy drop = Σ_layer `drop[layer][choice]` (the additive
//!   QoR assumption of [`super::model`]);
//! * relative multiplier power = Σ_layer `frac_layer · ratio_choice`
//!   (exact, from [`crate::accel::PowerModel`] fractions and
//!   [`crate::circuit::cost::CircuitCost`] power ratios — the hardware
//!   side needs no estimator).
//!
//! The search is the classic budgeted heuristic pair: a **greedy** pass
//! that repeatedly takes the single-layer change with the best
//! power-saving per unit of predicted drop that still fits the budget,
//! then a seeded **local-search** refinement proposing random single-layer
//! reassignments and accepting strict improvements. Everything is a pure
//! function of `(space, budget, iters, seed)` — never of thread timing —
//! so a multi-budget sweep fanned over `cgp::campaign::map_parallel`
//! is byte-identical for any `--jobs` value.

use crate::data::rng::Xoshiro256;

/// Tie-break / division floor for zero-predicted-drop moves.
const EPS_DROP: f64 = 1e-12;

/// The per-layer objective tables the search runs on.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// `drop[layer][choice]`: predicted accuracy drop of putting `choice`
    /// into `layer` alone. `drop[layer][0] == 0` (exact).
    pub drop: Vec<Vec<f64>>,
    /// `power[layer][choice]`: contribution of `(layer, choice)` to the
    /// whole-accelerator relative multiplier power, in percent
    /// (`frac_layer · power_ratio_choice · 100`). `power[layer][0]` is the
    /// layer's exact contribution.
    pub power: Vec<Vec<f64>>,
}

impl SearchSpace {
    /// Conv layers in the network.
    pub fn n_layers(&self) -> usize {
        self.drop.len()
    }

    /// Options per layer (candidates + 1 for the exact multiplier).
    pub fn n_choices(&self) -> usize {
        self.drop.first().map_or(0, Vec::len)
    }

    /// Predicted accuracy drop of an assignment (additive model).
    pub fn predicted_drop(&self, a: &[usize]) -> f64 {
        a.iter()
            .enumerate()
            .map(|(l, &c)| self.drop[l][c])
            .sum()
    }

    /// Relative multiplier power [%] of an assignment.
    pub fn power_pct(&self, a: &[usize]) -> f64 {
        a.iter()
            .enumerate()
            .map(|(l, &c)| self.power[l][c])
            .sum()
    }

    /// Greedy construction: from the all-exact assignment, repeatedly
    /// apply the single-layer change with the highest power saving per
    /// unit of *additional* predicted drop that keeps the total within
    /// `budget`. Ties break on `(layer, choice)` order; every accepted
    /// move strictly lowers power, so the loop terminates.
    pub fn greedy(&self, budget: f64) -> Vec<usize> {
        let mut a = vec![0usize; self.n_layers()];
        let mut total_drop = 0.0;
        loop {
            let mut best: Option<(f64, usize, usize)> = None; // (score, layer, choice)
            for l in 0..self.n_layers() {
                for c in 0..self.n_choices() {
                    if c == a[l] {
                        continue;
                    }
                    let d_drop = self.drop[l][c] - self.drop[l][a[l]];
                    let d_power = self.power[l][c] - self.power[l][a[l]];
                    if d_power >= 0.0 || total_drop + d_drop > budget {
                        continue;
                    }
                    let score = -d_power / d_drop.max(EPS_DROP);
                    if best.map_or(true, |(s, _, _)| score > s) {
                        best = Some((score, l, c));
                    }
                }
            }
            match best {
                Some((_, l, c)) => {
                    total_drop += self.drop[l][c] - self.drop[l][a[l]];
                    a[l] = c;
                }
                None => return a,
            }
        }
    }

    /// Seeded local-search refinement: `iters` proposals of one random
    /// `(layer, choice)` reassignment. A proposal is accepted when it
    /// stays within `budget` and strictly lowers power (or matches power
    /// with lower predicted drop); occasionally (1-in-8, RNG-driven) a
    /// budget-*freeing* move (lower drop at worse power) is accepted as a
    /// kick so the walk can escape greedy's stranded-budget local optima.
    /// The best feasible assignment seen — which includes the start — is
    /// returned, so refinement never loses ground. Deterministic in
    /// `(start, budget, iters, seed)`.
    pub fn local_search(
        &self,
        mut a: Vec<usize>,
        budget: f64,
        iters: u64,
        seed: u64,
    ) -> Vec<usize> {
        if self.n_layers() == 0 || self.n_choices() < 2 {
            return a;
        }
        let mut rng = Xoshiro256::new(seed);
        let mut drop = self.predicted_drop(&a);
        let mut power = self.power_pct(&a);
        let mut best = a.clone();
        let (mut best_power, mut best_drop) = (power, drop);
        for _ in 0..iters {
            let l = rng.next_usize(self.n_layers());
            let c = rng.next_usize(self.n_choices());
            let kick = rng.next_usize(8) == 0;
            if c == a[l] {
                continue;
            }
            let nd = drop + self.drop[l][c] - self.drop[l][a[l]];
            let np = power + self.power[l][c] - self.power[l][a[l]];
            if nd > budget {
                continue;
            }
            let improves =
                np < power - EPS_DROP || (np <= power + EPS_DROP && nd < drop - EPS_DROP);
            if improves || (kick && nd < drop - EPS_DROP) {
                a[l] = c;
                drop = nd;
                power = np;
                if np < best_power - EPS_DROP
                    || (np <= best_power + EPS_DROP && nd < best_drop - EPS_DROP)
                {
                    best = a.clone();
                    best_power = np;
                    best_drop = nd;
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two layers, two candidates. Layer 1 holds 90 % of the power;
    /// candidate 1 is cheap/low-error, candidate 2 cheaper/high-error.
    fn space() -> SearchSpace {
        SearchSpace {
            drop: vec![vec![0.0, 0.01, 0.05], vec![0.0, 0.02, 0.10]],
            power: vec![
                vec![10.0, 6.0, 3.0],  // layer 0: 10 % of total
                vec![90.0, 54.0, 27.0], // layer 1: 90 % of total
            ],
        }
    }

    #[test]
    fn objectives_are_separable_sums() {
        let s = space();
        assert_eq!(s.n_layers(), 2);
        assert_eq!(s.n_choices(), 3);
        assert!((s.power_pct(&[0, 0]) - 100.0).abs() < 1e-12);
        assert!((s.power_pct(&[2, 1]) - 57.0).abs() < 1e-12);
        assert!((s.predicted_drop(&[2, 1]) - 0.07).abs() < 1e-12);
    }

    #[test]
    fn greedy_respects_budget_and_prefers_big_layers() {
        let s = space();
        // zero budget (with zero-drop floor): nothing fits
        let a = s.greedy(-1.0);
        assert_eq!(a, vec![0, 0]);
        // tight budget: the high-share layer's low-error candidate first
        let a = s.greedy(0.02);
        assert_eq!(a[1], 1, "layer 1 saves 36 % for 0.02 drop: {a:?}");
        assert!(s.predicted_drop(&a) <= 0.02 + 1e-12);
        // generous budget: everything goes maximally approximate
        let a = s.greedy(1.0);
        assert_eq!(a, vec![2, 2]);
    }

    #[test]
    fn local_search_only_improves_and_is_deterministic() {
        let s = space();
        let start = s.greedy(0.07);
        let p0 = s.power_pct(&start);
        let a = s.local_search(start.clone(), 0.07, 500, 42);
        let b = s.local_search(start.clone(), 0.07, 500, 42);
        assert_eq!(a, b, "same seed, same walk");
        assert!(s.power_pct(&a) <= p0 + 1e-12);
        assert!(s.predicted_drop(&a) <= 0.07 + 1e-12);
        // a different seed still never worsens the start
        let c = s.local_search(start, 0.07, 500, 7);
        assert!(s.power_pct(&c) <= p0 + 1e-12);
    }

    #[test]
    fn local_search_escapes_a_greedy_miss() {
        // greedy takes layer-0's ratio-best move first and strands the
        // budget; local search can reach the better single big move
        let s = SearchSpace {
            drop: vec![vec![0.0, 0.001], vec![0.0, 0.05]],
            power: vec![vec![50.0, 45.0], vec![50.0, 10.0]],
        };
        let g = s.greedy(0.05);
        // greedy spends 0.001 on layer 0, then cannot afford layer 1
        assert_eq!(g, vec![1, 0]);
        let refined = s.local_search(g, 0.05, 2_000, 1);
        assert_eq!(refined, vec![0, 1], "the 40-point saving wins");
    }
}
