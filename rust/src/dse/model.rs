//! The QoR predictor: a cheap, deterministic substitute for autoAx's
//! ML-based quality estimators.
//!
//! autoAx (Mrazek et al.) fits estimation models of quality-of-result and
//! hardware cost from a small sample of real evaluations, then uses them
//! to prune a combinatorial configuration space. We keep the shape of
//! that idea but shrink the estimator to something dependency-free and
//! exactly reproducible: per conv layer, the accuracy drop caused by
//! replacing that layer's multiplier is modelled as a *linear* function
//! of the multiplier's circuit-level error metrics,
//!
//! ```text
//! drop(layer, m) ≈ β_layer · [1, MAE%, ER%, WCE%]
//! ```
//!
//! fitted by ridge-regularised least squares over the probe campaign's
//! measured points. The tiny ridge term keeps the normal equations
//! solvable when the probe budget is smaller than the feature count
//! (autoAx's "few real evaluations" regime); predictions are clamped at
//! zero (an approximate multiplier never *predictably* helps accuracy).
//! The hardware side needs no estimator at all — relative power is an
//! analytic sum over `CircuitCost` ratios (see [`super::search`]).

use crate::resilience::MultiplierSummary;

/// Features of one multiplier: intercept + the three error metrics the
/// paper's Table II leads with.
pub const N_FEATURES: usize = 4;

/// Feature vector of a multiplier summary.
pub fn features(m: &MultiplierSummary) -> [f64; N_FEATURES] {
    [1.0, m.mae_pct, m.er_pct, m.wce_pct]
}

/// One probe observation: `(layer, multiplier features, measured drop)`.
pub type ProbeSample = (usize, [f64; N_FEATURES], f64);

/// The fitted per-layer additive accuracy-drop model.
#[derive(Debug, Clone)]
pub struct QorModel {
    betas: Vec<[f64; N_FEATURES]>,
    /// Root-mean-square residual over the training (probe) sample.
    pub fit_rmse: f64,
    /// Training-sample size.
    pub n_samples: usize,
}

impl QorModel {
    /// Fit one ridge least-squares regression per layer from the probe
    /// sample. Layers with no samples get an all-zero (exact) model.
    pub fn fit(samples: &[ProbeSample], n_layers: usize) -> QorModel {
        let mut betas = vec![[0.0f64; N_FEATURES]; n_layers];
        for (layer, beta) in betas.iter_mut().enumerate() {
            let xs: Vec<[f64; N_FEATURES]> = samples
                .iter()
                .filter(|s| s.0 == layer)
                .map(|s| s.1)
                .collect();
            let ys: Vec<f64> = samples
                .iter()
                .filter(|s| s.0 == layer)
                .map(|s| s.2)
                .collect();
            if !xs.is_empty() {
                *beta = ridge_lsq(&xs, &ys, 1e-6);
            }
        }
        let mut sq = 0.0;
        for (layer, x, y) in samples {
            let pred = dot(&betas[*layer], x);
            sq += (pred - y) * (pred - y);
        }
        let n = samples.len();
        QorModel {
            betas,
            fit_rmse: if n == 0 { 0.0 } else { (sq / n as f64).sqrt() },
            n_samples: n,
        }
    }

    /// Predicted accuracy drop of putting a multiplier with features `x`
    /// into `layer` (all other layers exact). Clamped at zero.
    pub fn predict(&self, layer: usize, x: &[f64; N_FEATURES]) -> f64 {
        dot(&self.betas[layer], x).max(0.0)
    }
}

fn dot(a: &[f64; N_FEATURES], b: &[f64; N_FEATURES]) -> f64 {
    a.iter().zip(b.iter()).map(|(p, q)| p * q).sum()
}

/// Solve `(XᵀX + λI) β = Xᵀy` by Gaussian elimination with partial
/// pivoting. λ > 0 guarantees the system is well-posed for any sample
/// count, so the fit is total and deterministic.
fn ridge_lsq(xs: &[[f64; N_FEATURES]], ys: &[f64], lambda: f64) -> [f64; N_FEATURES] {
    const K: usize = N_FEATURES;
    let mut a = [[0.0f64; K + 1]; K]; // augmented [XᵀX + λI | Xᵀy]
    for (x, &y) in xs.iter().zip(ys.iter()) {
        for i in 0..K {
            for j in 0..K {
                a[i][j] += x[i] * x[j];
            }
            a[i][K] += x[i] * y;
        }
    }
    for (i, row) in a.iter_mut().enumerate() {
        row[i] += lambda;
    }
    // forward elimination with partial pivoting
    for col in 0..K {
        let mut pivot = col;
        for row in (col + 1)..K {
            if a[row][col].abs() > a[pivot][col].abs() {
                pivot = row;
            }
        }
        a.swap(col, pivot);
        let p = a[col][col];
        if p.abs() < 1e-300 {
            continue; // λI makes this unreachable for finite inputs
        }
        for row in (col + 1)..K {
            let f = a[row][col] / p;
            for j in col..=K {
                a[row][j] -= f * a[col][j];
            }
        }
    }
    // back substitution
    let mut beta = [0.0f64; K];
    for col in (0..K).rev() {
        let mut v = a[col][K];
        for j in (col + 1)..K {
            v -= a[col][j] * beta[j];
        }
        beta[col] = if a[col][col].abs() < 1e-300 {
            0.0
        } else {
            v / a[col][col]
        };
    }
    beta
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_linear_relation() {
        // drop = 0.01 + 0.5*mae on layer 0; enough samples to determine it
        let samples: Vec<ProbeSample> = [0.0f64, 0.5, 1.0, 2.0, 4.0]
            .iter()
            .map(|&mae| {
                (
                    0usize,
                    [1.0, mae, 2.0 * mae, 3.0 * mae],
                    0.01 + 0.5 * mae,
                )
            })
            .collect();
        let m = QorModel::fit(&samples, 2);
        assert!(m.fit_rmse < 1e-4, "rmse {}", m.fit_rmse);
        let pred = m.predict(0, &[1.0, 3.0, 6.0, 9.0]);
        assert!((pred - 1.51).abs() < 1e-3, "{pred}");
        // the unprobed layer predicts zero
        assert_eq!(m.predict(1, &[1.0, 3.0, 6.0, 9.0]), 0.0);
    }

    #[test]
    fn underdetermined_fit_is_total_and_interpolates() {
        // fewer samples than features: ridge still yields a model that
        // reproduces the probed points closely
        let samples = vec![
            (0usize, [1.0, 1.0, 10.0, 2.0], 0.05),
            (0usize, [1.0, 4.0, 40.0, 8.0], 0.20),
        ];
        let m = QorModel::fit(&samples, 1);
        for (l, x, y) in &samples {
            assert!((m.predict(*l, x) - y).abs() < 1e-3);
        }
    }

    #[test]
    fn predictions_clamp_at_zero() {
        let samples = vec![
            (0usize, [1.0, 1.0, 1.0, 1.0], -0.5),
            (0usize, [1.0, 2.0, 2.0, 2.0], -1.0),
        ];
        let m = QorModel::fit(&samples, 1);
        assert_eq!(m.predict(0, &[1.0, 3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn empty_fit_is_all_zero() {
        let m = QorModel::fit(&[], 3);
        assert_eq!(m.n_samples, 0);
        assert_eq!(m.fit_rmse, 0.0);
        for l in 0..3 {
            assert_eq!(m.predict(l, &[1.0, 9.0, 9.0, 9.0]), 0.0);
        }
    }
}
