//! Design-space exploration (DSE): heterogeneous per-layer multiplier
//! assignment, autoAx-style (DESIGN.md §8).
//!
//! The paper's case study ends by *selecting one approximate multiplier*
//! for the whole network. The scalable version of that step (autoAx,
//! Mrazek et al. — PAPERS.md) assigns each conv layer its **own** library
//! multiplier: fit cheap quality/cost estimators from a small sample of
//! real evaluations, prune the combinatorial assignment space with them,
//! and verify only the predicted Pareto front. This module is that
//! pipeline in three deterministic stages:
//!
//! 1. **probe** ([`probe_stage`]) — a per-layer resilience campaign
//!    ([`crate::resilience::per_layer_campaign_cached`]) over a small,
//!    power-spread subset of the candidates measures each layer's
//!    accuracy sensitivity; [`model::QorModel`] fits the additive
//!    least-squares QoR predictor from those points. Power needs no
//!    probing: it is an analytic sum of per-layer MAC-energy ratios from
//!    [`crate::circuit::cost::CircuitCost`].
//! 2. **search** ([`search_stage`]) — greedy + seeded local-search
//!    refinement over the *predicted* objectives, one run per point of an
//!    accuracy-budget ladder, fanned over `cgp::campaign::map_parallel`.
//! 3. **verify** ([`run_dse`]) — the predicted-Pareto assignments (plus
//!    every uniform single-multiplier configuration, so the report can
//!    always compare against the paper's whole-network selection) run on
//!    the real inference backend; the report carries predicted vs
//!    measured drops and the measured-front/best-uniform comparison.
//!
//! Every stage is a pure function of its inputs and the shared
//! [`EvalCache`] only memoises values the pipeline would recompute
//! identically, so reports are byte-identical for any `--jobs` value and
//! for HTTP vs in-process runs (tested).

pub mod model;
pub mod search;

use std::collections::BTreeSet;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::accel::PowerModel;
use crate::cgp::campaign::{default_workers, map_parallel_progress};
use crate::cgp::pareto::non_dominated_indices;
use crate::coordinator::{Coordinator, KernelKind};
use crate::library::LibrarySource;
use crate::obs::progress::Progress;
use crate::obs::trace;
use crate::resilience::cache::{EvalCache, EvalKey};
use crate::resilience::{
    per_layer_campaign_progress, standard_multipliers, Fig4Report, MultiplierSummary,
};
use crate::runtime::{exact_lut, TestSet, LUT_LEN};

pub use model::QorModel;
pub use search::SearchSpace;

/// Configuration of one DSE run. [`DseConfig::new`] is the single source
/// of defaults for the CLI, the HTTP endpoint and the tests — which is
/// what lets an HTTP run be compared byte-for-byte with an in-process one.
#[derive(Debug, Clone)]
pub struct DseConfig {
    /// Network under exploration.
    pub model: String,
    /// Accuracy budget: the verified front targets drops within this.
    pub max_accuracy_drop: f64,
    /// Probe budget: candidates measured per layer in the probe campaign.
    pub probe_multipliers: usize,
    /// Per-layer candidate pool size (library Pareto pre-filter cap).
    pub candidates: usize,
    /// Local-search proposals per budget point.
    pub search_iters: u64,
    /// Points on the accuracy-budget ladder (each yields one search run).
    pub budget_points: usize,
    /// Most predicted-front assignments taken into verification
    /// (uniform configurations are always verified on top of this).
    pub verify_limit: usize,
    /// Pool workers for probe/search/verify (output-identical for any N).
    pub jobs: usize,
    /// Root seed of the local-search walks.
    pub seed: u64,
    /// Kernel variant on the PJRT backend (ignored by native).
    pub kernel: KernelKind,
}

impl DseConfig {
    /// Defaults for `model`.
    pub fn new(model: impl Into<String>) -> DseConfig {
        DseConfig {
            model: model.into(),
            max_accuracy_drop: 0.05,
            probe_multipliers: 4,
            candidates: 8,
            search_iters: 400,
            budget_points: 4,
            verify_limit: 8,
            jobs: default_workers(),
            seed: 0xD5E,
            kernel: KernelKind::Jnp,
        }
    }

    /// Parse a `--probe-budget` value: a named tier or a multiplier count.
    pub fn parse_probe_budget(s: &str) -> Result<usize> {
        let n = match s {
            "small" => 2,
            "medium" => 4,
            "large" => 8,
            other => other.parse().map_err(|_| {
                anyhow!("invalid probe budget `{other}` (small|medium|large or a multiplier count)")
            })?,
        };
        ensure!(n >= 1, "probe budget must be at least 1");
        Ok(n)
    }
}

/// Probe-stage output: the measured per-layer campaign plus which
/// candidate indices were probed.
#[derive(Debug, Clone)]
pub struct ProbeOutcome {
    /// The measured Fig. 4-style campaign over the probe roster.
    pub fig4: Fig4Report,
    /// Indices (into the candidate slice) that were measured.
    pub probed: Vec<usize>,
    /// Accuracy evaluations *requested* (grid + golden reference) —
    /// shared-cache hits included, which keeps reports byte-identical
    /// however warm the cache is. Real backend work is tracked
    /// separately as cache-miss deltas in `coordinator::metrics`.
    pub evals: usize,
}

/// Space-construction output: objective tables + the fitted QoR model.
#[derive(Debug, Clone)]
pub struct SpaceOutcome {
    /// Per-layer objective tables (choice 0 = exact).
    pub space: SearchSpace,
    /// The fitted accuracy-drop predictor.
    pub qor: QorModel,
}

/// Search-stage output: deduplicated candidate assignments in
/// budget-ladder order.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Candidate assignments (`a[layer] = choice`, 0 = exact).
    pub assignments: Vec<Vec<usize>>,
    /// Local-search proposals evaluated across all budget points.
    pub iters: u64,
}

/// One verified configuration in the report.
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// Per-layer multiplier ids (`"exact"` for the exact multiplier).
    pub assignment: Vec<String>,
    /// Whether every layer carries the same multiplier.
    pub uniform: bool,
    /// Model-predicted accuracy drop.
    pub predicted_drop: f64,
    /// Relative multiplier power [%] (analytic — not an estimate).
    pub power_pct: f64,
    /// Measured accuracy on the real backend.
    pub accuracy: f64,
    /// Measured accuracy drop vs the golden reference.
    pub accuracy_drop: f64,
}

/// Full DSE report.
#[derive(Debug, Clone)]
pub struct DseReport {
    /// Network explored.
    pub model: String,
    /// Evaluation-split size.
    pub images: usize,
    /// The accuracy budget the search targeted.
    pub max_accuracy_drop: f64,
    /// Golden (exact-multiplier) accuracy.
    pub reference_accuracy: f64,
    /// Candidate ids in roster order.
    pub candidates: Vec<String>,
    /// Provable WCE ceiling per candidate [% of max output], index-aligned
    /// with `candidates`. The QoR model is fit on *sampled* error columns,
    /// which can undershoot on wide operands; the static bound is the
    /// sound ceiling a consumer can audit the roster against.
    pub candidate_wce_bound_pct: Vec<f64>,
    /// Candidates statically proven exact (index-aligned with
    /// `candidates`) — their true error contribution is provably zero
    /// regardless of sampling.
    pub candidate_exact_proven: Vec<bool>,
    /// Candidates measured in the probe stage.
    pub probe_multipliers: usize,
    /// Accuracy evaluations requested by the probe stage (cache hits
    /// included — deterministic across cache states).
    pub probe_evals: usize,
    /// QoR-model training residual (RMSE over probe points).
    pub qor_fit_rmse: f64,
    /// QoR-model training-sample size.
    pub qor_samples: usize,
    /// Local-search proposals across all budget points.
    pub search_iters: u64,
    /// Every verified configuration (exact anchor first, then the
    /// predicted front, then the uniform sweeps), in deterministic order.
    pub verified: Vec<DsePoint>,
    /// Measured (accuracy drop, power) Pareto front over `verified`,
    /// ascending power. Because `verified` always contains every uniform
    /// configuration, this front weakly dominates the best uniform pick
    /// by construction.
    pub front: Vec<DsePoint>,
    /// Cheapest uniform configuration whose measured drop fits the
    /// budget (the paper's whole-network selection; the exact anchor
    /// guarantees one exists).
    pub best_uniform: Option<DsePoint>,
    /// Mean |predicted − measured| drop over the verified set.
    pub prediction_mae: f64,
}

/// `k` indices evenly spread over `0..n` (always including both ends for
/// `k ≥ 2`), deduplicated — the probe roster should span the candidates'
/// power range, not take a prefix.
fn spread_indices(n: usize, k: usize) -> Vec<usize> {
    if n == 0 {
        return Vec::new();
    }
    if k >= n {
        return (0..n).collect();
    }
    if k <= 1 {
        return vec![0];
    }
    let mut out: Vec<usize> = (0..k).map(|i| i * (n - 1) / (k - 1)).collect();
    out.dedup();
    out
}

fn is_uniform(a: &[usize]) -> bool {
    a.windows(2).all(|w| w[0] == w[1])
}

/// Cache identity of an assignment: the golden sentinel when all-exact,
/// the multiplier id when uniform (sharing entries with `/v1/select` and
/// Table-II-style evaluations), the joined per-layer ids otherwise.
fn assignment_key(a: &[usize], cands: &[MultiplierSummary]) -> String {
    if a.iter().all(|&c| c == 0) {
        return EvalKey::GOLDEN.to_string();
    }
    if let Some(&c0) = a.first() {
        if c0 != 0 && a.iter().all(|&c| c == c0) {
            return cands[c0 - 1].id.clone();
        }
    }
    a.iter()
        .map(|&c| if c == 0 { "exact" } else { cands[c - 1].id.as_str() })
        .collect::<Vec<_>>()
        .join("+")
}

/// Concatenated per-layer LUT rows of an assignment.
fn assignment_luts(a: &[usize], cands: &[MultiplierSummary], exact: &[i32]) -> Vec<i32> {
    let mut luts = Vec::with_capacity(a.len() * LUT_LEN);
    for &c in a {
        match c {
            0 => luts.extend_from_slice(exact),
            c => luts.extend_from_slice(&cands[c - 1].lut),
        }
    }
    luts
}

/// Stage 1: measure per-layer sensitivity of a power-spread probe subset
/// of the candidates (exact reference included for the power model).
pub fn probe_stage(
    coord: &Coordinator,
    cfg: &DseConfig,
    mults: &[MultiplierSummary],
    testset: &TestSet,
    cache: Option<&EvalCache>,
) -> Result<ProbeOutcome> {
    probe_stage_progress(coord, cfg, mults, testset, cache, None)
}

/// [`probe_stage`] with an optional [`Progress`] handle: enters stage
/// `probe` sized to the probe campaign's grid and ticks per delivered
/// point (side-channel only — the outcome is byte-identical).
pub fn probe_stage_progress(
    coord: &Coordinator,
    cfg: &DseConfig,
    mults: &[MultiplierSummary],
    testset: &TestSet,
    cache: Option<&EvalCache>,
    progress: Option<&Progress>,
) -> Result<ProbeOutcome> {
    let _span = trace::span("dse", "probe");
    ensure!(
        mults.len() >= 2,
        "DSE needs the exact reference plus at least one approximate candidate"
    );
    let cands = &mults[1..];
    let probed = spread_indices(cands.len(), cfg.probe_multipliers.max(1));
    let mut roster = vec![mults[0].clone()];
    roster.extend(probed.iter().map(|&i| cands[i].clone()));
    let fig4 = per_layer_campaign_progress(
        coord,
        &cfg.model,
        &roster,
        testset,
        cfg.kernel,
        cfg.jobs,
        cache,
        progress,
        "probe",
    )?;
    let evals = fig4.points.len() + 1; // grid + the golden reference
    Ok(ProbeOutcome {
        fig4,
        probed,
        evals,
    })
}

/// Stage 1b: fit the QoR model from the probe campaign and assemble the
/// per-layer objective tables. Probed `(layer, candidate)` cells keep
/// their *measured* drop; everything else is model-predicted (clamped at
/// zero). Power cells are analytic ratios — no estimation error.
pub fn build_space(
    probe: &ProbeOutcome,
    mults: &[MultiplierSummary],
    pm: &PowerModel,
) -> SpaceOutcome {
    let cands = &mults[1..];
    let n_layers = pm.layer_mults.len();
    // training sample: every measured point, features looked up by id
    // (the exact row anchors the zero-error/zero-drop end)
    let mut samples: Vec<model::ProbeSample> = Vec::with_capacity(probe.fig4.points.len());
    for p in &probe.fig4.points {
        if let Some(m) = mults.iter().find(|m| m.id == p.multiplier) {
            samples.push((p.layer, model::features(m), p.accuracy_drop));
        }
    }
    let qor = QorModel::fit(&samples, n_layers);
    // measured overrides for probed candidates
    let mut measured = vec![vec![None::<f64>; cands.len()]; n_layers];
    for &ci in &probe.probed {
        let id = &cands[ci].id;
        for p in probe.fig4.points.iter().filter(|p| &p.multiplier == id) {
            measured[p.layer][ci] = Some(p.accuracy_drop);
        }
    }
    let mut drop = Vec::with_capacity(n_layers);
    let mut power = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let frac = pm.layer_fraction(l);
        let mut dl = Vec::with_capacity(cands.len() + 1);
        let mut pl = Vec::with_capacity(cands.len() + 1);
        dl.push(0.0);
        pl.push(frac * 100.0);
        for (ci, c) in cands.iter().enumerate() {
            dl.push(match measured[l][ci] {
                Some(d) => d,
                None => qor.predict(l, &model::features(c)),
            });
            pl.push(frac * c.rel_power_pct);
        }
        drop.push(dl);
        power.push(pl);
    }
    SpaceOutcome {
        space: SearchSpace { drop, power },
        qor,
    }
}

/// Stage 2: one greedy + local-search run per accuracy-budget ladder
/// point, fanned over the deterministic job pool; results deduplicate in
/// ladder order.
pub fn search_stage(space: &SearchSpace, cfg: &DseConfig) -> SearchOutcome {
    search_stage_progress(space, cfg, None)
}

/// [`search_stage`] with an optional [`Progress`] handle: enters stage
/// `search` with one tick per budget-ladder point.
pub fn search_stage_progress(
    space: &SearchSpace,
    cfg: &DseConfig,
    progress: Option<&Progress>,
) -> SearchOutcome {
    let _span = trace::span("dse", "search");
    let points = cfg.budget_points.max(1);
    if let Some(p) = progress {
        p.set_stage("search", points as u64);
    }
    let budgets: Vec<f64> = (0..points)
        .map(|i| cfg.max_accuracy_drop * (i + 1) as f64 / points as f64)
        .collect();
    let results = map_parallel_progress(budgets, cfg.jobs.max(1), progress, |i, budget, _scratch| {
        let start = space.greedy(budget);
        space.local_search(
            start,
            budget,
            cfg.search_iters,
            cfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )
    });
    let mut seen = BTreeSet::new();
    let mut assignments = Vec::new();
    for a in results {
        if seen.insert(a.clone()) {
            assignments.push(a);
        }
    }
    SearchOutcome {
        assignments,
        iters: points as u64 * cfg.search_iters,
    }
}

/// The full pipeline: probe → fit → search → verify → report.
///
/// `testset` is the evaluation split (the HTTP endpoint and the
/// determinism tests use [`TestSet::synthetic`]); `cache` memoises every
/// real evaluation under [`EvalKey`]s shared with `/v1/select` and the
/// campaign endpoints.
pub fn run_dse(
    coord: &Coordinator,
    lib: Option<&LibrarySource>,
    cfg: &DseConfig,
    testset: &TestSet,
    cache: &EvalCache,
) -> Result<DseReport> {
    run_dse_progress(coord, lib, cfg, testset, cache, None)
}

/// [`run_dse`] with an optional [`Progress`] handle: the pipeline walks
/// the stages `probe` → `fit` → `search` → `verify`, each sized to its
/// own work-item count, so `GET /v1/jobs/{id}` shows live per-stage
/// progress for DSE jobs. Progress and the `dse` trace spans are side
/// channels; the report is byte-identical with them on or off (tested).
pub fn run_dse_progress(
    coord: &Coordinator,
    lib: Option<&LibrarySource>,
    cfg: &DseConfig,
    testset: &TestSet,
    cache: &EvalCache,
    progress: Option<&Progress>,
) -> Result<DseReport> {
    let _span = trace::span_arg("dse", "run", "model", || cfg.model.clone());
    let t0 = Instant::now();
    ensure!(
        cfg.max_accuracy_drop.is_finite() && cfg.max_accuracy_drop >= 0.0,
        "max_accuracy_drop must be a non-negative finite number"
    );
    ensure!(testset.n > 0, "evaluation split is empty");
    let meta = coord
        .manifest()
        .model(&cfg.model)
        .ok_or_else(|| anyhow!("unknown model `{}`", cfg.model))?
        .clone();
    let pm = PowerModel::from_manifest(&meta);
    let mults = standard_multipliers(lib, 10, cfg.candidates.max(1))?;
    ensure!(
        mults.first().map(|m| m.is_exact).unwrap_or(false),
        "multiplier roster must lead with the exact reference"
    );

    // stage 1: probe + fit. The report carries deterministic *requested*
    // counts (identical however warm the cache is); the Prometheus
    // counters below record *real* backend evaluations as cache-miss
    // deltas — best-effort attribution when runs share one cache.
    let probe_misses_before = cache.misses();
    let probe = probe_stage_progress(coord, cfg, &mults, testset, Some(cache), progress)?;
    let probe_real_evals = cache.misses().saturating_sub(probe_misses_before);
    let golden = probe.fig4.reference_accuracy;
    let so = {
        let _s = trace::span("dse", "fit");
        if let Some(p) = progress {
            p.set_stage("fit", 1);
        }
        let so = build_space(&probe, &mults, &pm);
        if let Some(p) = progress {
            p.tick();
        }
        so
    };
    let cands = &mults[1..];
    let n_layers = so.space.n_layers();

    // stage 2: model-guided search over the budget ladder
    let search = search_stage_progress(&so.space, cfg, progress);

    // stage 3: verify the predicted front + every uniform configuration
    let all_exact = vec![0usize; n_layers];
    let objs: Vec<Vec<f64>> = search
        .assignments
        .iter()
        .map(|a| vec![so.space.predicted_drop(a), so.space.power_pct(a)])
        .collect();
    let mut verify: Vec<Vec<usize>> = non_dominated_indices(&objs)
        .into_iter()
        .take(cfg.verify_limit.max(1))
        .map(|i| search.assignments[i].clone())
        .collect();
    for c in 1..=cands.len() {
        let u = vec![c; n_layers];
        if !verify.contains(&u) {
            verify.push(u);
        }
    }
    verify.retain(|a| a != &all_exact); // the anchor is the golden run itself
    let images = Arc::new(testset.images.clone());
    let exact = exact_lut();
    let verify_misses_before = cache.misses();
    let verify_span = trace::span("dse", "verify");
    if let Some(p) = progress {
        p.set_stage("verify", verify.len() as u64);
    }
    let accs = map_parallel_progress(verify.clone(), cfg.jobs.max(1), progress, |_, a, _scratch| {
        let _s = trace::span("dse", "verify-eval");
        cache.get_or_compute(
            EvalKey::whole(&cfg.model, &assignment_key(&a, cands), testset.n),
            || {
                coord.accuracy(
                    &cfg.model,
                    cfg.kernel,
                    images.clone(),
                    &testset.labels,
                    Arc::new(assignment_luts(&a, cands, &exact)),
                )
            },
        )
    });
    drop(verify_span);
    let verify_real_evals = cache.misses().saturating_sub(verify_misses_before);
    let mut verified = Vec::with_capacity(verify.len() + 1);
    verified.push(DsePoint {
        assignment: vec!["exact".to_string(); n_layers],
        uniform: true,
        predicted_drop: 0.0,
        power_pct: so.space.power_pct(&all_exact),
        accuracy: golden,
        accuracy_drop: 0.0,
    });
    for (a, acc) in verify.into_iter().zip(accs) {
        let acc = acc?;
        verified.push(DsePoint {
            assignment: a
                .iter()
                .map(|&c| {
                    if c == 0 {
                        "exact".to_string()
                    } else {
                        cands[c - 1].id.clone()
                    }
                })
                .collect(),
            uniform: is_uniform(&a),
            predicted_drop: so.space.predicted_drop(&a),
            power_pct: so.space.power_pct(&a),
            accuracy: acc,
            accuracy_drop: golden - acc,
        });
    }

    // measured Pareto front (ascending power) + the uniform baseline
    let objs: Vec<Vec<f64>> = verified
        .iter()
        .map(|p| vec![p.accuracy_drop, p.power_pct])
        .collect();
    let mut front: Vec<DsePoint> = non_dominated_indices(&objs)
        .into_iter()
        .map(|i| verified[i].clone())
        .collect();
    front.sort_by(|x, y| x.power_pct.total_cmp(&y.power_pct));
    let best_uniform = verified
        .iter()
        .filter(|p| p.uniform && p.accuracy_drop <= cfg.max_accuracy_drop)
        .min_by(|x, y| {
            x.power_pct
                .total_cmp(&y.power_pct)
                .then(x.accuracy_drop.total_cmp(&y.accuracy_drop))
        })
        .cloned();
    let prediction_mae = if verified.len() > 1 {
        verified[1..]
            .iter()
            .map(|p| (p.predicted_drop - p.accuracy_drop).abs())
            .sum::<f64>()
            / (verified.len() - 1) as f64
    } else {
        0.0
    };

    let m = coord.metrics_raw();
    m.dse_jobs.fetch_add(1, Ordering::Relaxed);
    m.dse_probe_evals.fetch_add(probe_real_evals, Ordering::Relaxed);
    m.dse_search_iters.fetch_add(search.iters, Ordering::Relaxed);
    m.dse_verify_runs.fetch_add(verify_real_evals, Ordering::Relaxed);
    m.dse_duration.record(t0.elapsed());

    Ok(DseReport {
        model: cfg.model.clone(),
        images: testset.n,
        max_accuracy_drop: cfg.max_accuracy_drop,
        reference_accuracy: golden,
        candidates: cands.iter().map(|c| c.id.clone()).collect(),
        candidate_wce_bound_pct: cands.iter().map(|c| c.wce_bound_pct).collect(),
        candidate_exact_proven: cands.iter().map(|c| c.exact_proven).collect(),
        probe_multipliers: probe.probed.len(),
        probe_evals: probe.evals,
        qor_fit_rmse: so.qor.fit_rmse,
        qor_samples: so.qor.n_samples,
        search_iters: search.iters,
        verified,
        front,
        best_uniform,
        prediction_mae,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_spans_the_range() {
        assert_eq!(spread_indices(8, 3), vec![0, 3, 7]);
        assert_eq!(spread_indices(8, 2), vec![0, 7]);
        assert_eq!(spread_indices(3, 8), vec![0, 1, 2]);
        assert_eq!(spread_indices(5, 1), vec![0]);
        assert_eq!(spread_indices(1, 3), vec![0]);
        assert!(spread_indices(0, 3).is_empty());
        // near-duplicate targets collapse
        let s = spread_indices(2, 5);
        assert_eq!(s, vec![0, 1]);
    }

    #[test]
    fn probe_budget_parsing() {
        assert_eq!(DseConfig::parse_probe_budget("small").unwrap(), 2);
        assert_eq!(DseConfig::parse_probe_budget("medium").unwrap(), 4);
        assert_eq!(DseConfig::parse_probe_budget("large").unwrap(), 8);
        assert_eq!(DseConfig::parse_probe_budget("6").unwrap(), 6);
        assert!(DseConfig::parse_probe_budget("0").is_err());
        assert!(DseConfig::parse_probe_budget("tiny").is_err());
    }

    #[test]
    fn uniformity_and_keys() {
        assert!(is_uniform(&[0, 0, 0]));
        assert!(is_uniform(&[2, 2]));
        assert!(is_uniform(&[1]));
        assert!(is_uniform(&[]));
        assert!(!is_uniform(&[1, 0]));
    }
}
