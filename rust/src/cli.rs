//! Dependency-free, clap-style command-line layer for the `evoapprox`
//! binary (the offline vendor set has no clap).
//!
//! Subcommands and flags are declared as const [`CommandSpec`]/[`FlagSpec`]
//! tables; [`parse`] validates argv against them, rejecting unknown
//! commands, unknown flags and missing values with errors that name the
//! valid alternatives — instead of the old hand-rolled parser's silent
//! ignore. Supported syntax:
//!
//! * `--flag value` and `--flag=value`;
//! * boolean switches (`--quick`) that take no value;
//! * negative numbers as values (`--seed -5`): only a leading `--` marks
//!   the next token as a flag;
//! * multi-token subcommands (`library compile`): the longest spec-name
//!   match over the leading tokens wins.

use std::collections::HashMap;
use std::fmt;

/// Declaration of one flag.
#[derive(Debug, Clone, Copy)]
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// `Some(placeholder)` if the flag takes a value, `None` for switches.
    pub value: Option<&'static str>,
    /// One-line help text.
    pub help: &'static str,
}

/// Declaration of one subcommand.
#[derive(Debug, Clone, Copy)]
pub struct CommandSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Accepted flags.
    pub flags: &'static [FlagSpec],
}

/// Everything that can go wrong while parsing argv.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    /// The first argument names no known subcommand.
    UnknownCommand {
        /// What was typed.
        command: String,
        /// Valid subcommand names.
        known: Vec<String>,
    },
    /// A `--flag` the subcommand does not accept.
    UnknownFlag {
        /// Subcommand being parsed.
        command: String,
        /// The offending flag (with `--`).
        flag: String,
        /// Flags the subcommand does accept.
        known: Vec<String>,
    },
    /// A bare token where a flag was expected.
    UnexpectedArg {
        /// Subcommand being parsed.
        command: String,
        /// The stray token.
        arg: String,
    },
    /// A value-taking flag at the end of argv or followed by another flag.
    MissingValue {
        /// The offending flag (with `--`).
        flag: String,
    },
    /// A value that failed to parse as the requested type.
    BadValue {
        /// The offending flag (with `--`).
        flag: String,
        /// The unparseable value.
        value: String,
    },
    /// An inline `=value` on a switch that takes none (`--quick=false`
    /// must not silently enable quick mode).
    UnexpectedValue {
        /// The offending flag (with `--`).
        flag: String,
        /// The rejected inline value.
        value: String,
    },
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::UnknownCommand { command, known } => write!(
                f,
                "unknown command `{command}` (expected one of: {})",
                known.join(", ")
            ),
            CliError::UnknownFlag {
                command,
                flag,
                known,
            } => {
                if known.is_empty() {
                    write!(f, "`{command}` takes no flags, got `{flag}`")
                } else {
                    write!(
                        f,
                        "unknown flag `{flag}` for `{command}` (valid: {})",
                        known.join(", ")
                    )
                }
            }
            CliError::UnexpectedArg { command, arg } => {
                write!(f, "unexpected argument `{arg}` after `{command}` (flags start with --)")
            }
            CliError::MissingValue { flag } => {
                write!(f, "flag `{flag}` requires a value")
            }
            CliError::BadValue { flag, value } => {
                write!(f, "invalid value `{value}` for `{flag}`")
            }
            CliError::UnexpectedValue { flag, value } => {
                write!(f, "flag `{flag}` takes no value (got `{value}`)")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Parsed command line: the subcommand plus its validated flags.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    /// Subcommand name (`"help"` when argv was empty or asked for help).
    pub command: String,
    flags: HashMap<String, String>,
}

fn known_flags(spec: &CommandSpec) -> Vec<String> {
    spec.flags.iter().map(|f| format!("--{}", f.name)).collect()
}

/// Parse argv (without the binary name) against the command table.
pub fn parse(specs: &[CommandSpec], args: &[String]) -> Result<Cli, CliError> {
    let command = args.first().cloned().unwrap_or_default();
    // `--help`/`-h` anywhere (the clap idiom `evoapprox evolve --help`)
    // short-circuits to help instead of tripping the unknown-flag check.
    if command.is_empty()
        || matches!(command.as_str(), "help" | "--help" | "-h")
        || args.iter().any(|a| a == "--help" || a == "-h")
    {
        return Ok(Cli {
            command: "help".to_string(),
            flags: HashMap::new(),
        });
    }
    // Multi-token subcommands (`library compile`): when the first two
    // tokens joined name a spec, that longer name wins over the
    // single-token prefix (`library`), and the flag scan starts after it.
    let (command, consumed) = match args.get(1) {
        Some(second) if !second.starts_with("--") => {
            let two = format!("{command} {second}");
            if specs.iter().any(|c| c.name == two) {
                (two, 2)
            } else {
                (command, 1)
            }
        }
        _ => (command, 1),
    };
    let spec = specs
        .iter()
        .find(|c| c.name == command)
        .ok_or_else(|| CliError::UnknownCommand {
            command: command.clone(),
            known: specs.iter().map(|c| c.name.to_string()).collect(),
        })?;
    let mut flags = HashMap::new();
    let mut i = consumed;
    while i < args.len() {
        let arg = &args[i];
        let Some(body) = arg.strip_prefix("--") else {
            return Err(CliError::UnexpectedArg {
                command,
                arg: arg.clone(),
            });
        };
        let (key, inline) = match body.split_once('=') {
            Some((k, v)) => (k, Some(v.to_string())),
            None => (body, None),
        };
        let flag_spec = spec
            .flags
            .iter()
            .find(|f| f.name == key)
            .ok_or_else(|| CliError::UnknownFlag {
                command: command.clone(),
                flag: format!("--{key}"),
                known: known_flags(spec),
            })?;
        let value = match (flag_spec.value.is_some(), inline) {
            (true, Some(v)) => v,
            (false, Some(v)) => {
                return Err(CliError::UnexpectedValue {
                    flag: format!("--{key}"),
                    value: v,
                })
            }
            (false, None) => "true".to_string(),
            (true, None) => match args.get(i + 1) {
                // a following `--whatever` is another flag, not a value; a
                // bare `-5` (negative number) is a legitimate value
                Some(v) if !v.starts_with("--") => {
                    i += 1;
                    v.clone()
                }
                _ => {
                    return Err(CliError::MissingValue {
                        flag: format!("--{key}"),
                    })
                }
            },
        };
        flags.insert(key.to_string(), value);
        i += 1;
    }
    Ok(Cli { command, flags })
}

impl Cli {
    /// Raw value of a flag, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Whether a switch (or any flag) was passed.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Typed flag lookup with a default; a present-but-unparseable value is
    /// an error (the old parser silently fell back to the default).
    pub fn flag<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                flag: format!("--{key}"),
                value: v.clone(),
            }),
        }
    }

    /// String flag with a default.
    pub fn flag_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

/// Render the full help text from the command table.
pub fn render_help(binary: &str, about: &str, specs: &[CommandSpec]) -> String {
    let mut out = format!("{binary} — {about}\n\nCOMMANDS\n");
    for c in specs {
        out.push_str(&format!("  {:<16} {}\n", c.name, c.about));
        for f in c.flags {
            let left = match f.value {
                Some(v) => format!("--{} <{v}>", f.name),
                None => format!("--{}", f.name),
            };
            out.push_str(&format!("      {left:<24} {}\n", f.help));
        }
    }
    out.push_str("\nRun with `help` (or no arguments) to print this text.\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const FLAGS: &[FlagSpec] = &[
        FlagSpec {
            name: "width",
            value: Some("BITS"),
            help: "operand width",
        },
        FlagSpec {
            name: "seed",
            value: Some("N"),
            help: "rng seed",
        },
        FlagSpec {
            name: "quick",
            value: None,
            help: "reduced budget",
        },
    ];
    const SPECS: &[CommandSpec] = &[
        CommandSpec {
            name: "evolve",
            about: "run evolution",
            flags: FLAGS,
        },
        CommandSpec {
            name: "info",
            about: "print info",
            flags: &[],
        },
        CommandSpec {
            name: "lib",
            about: "library ops",
            flags: &[],
        },
        CommandSpec {
            name: "lib compile",
            about: "compile the library",
            flags: FLAGS,
        },
    ];

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_switches_and_equals() {
        let cli = parse(SPECS, &args(&["evolve", "--width", "12", "--quick"])).unwrap();
        assert_eq!(cli.command, "evolve");
        assert_eq!(cli.flag("width", 8u32).unwrap(), 12);
        assert!(cli.has("quick"));
        assert!(!cli.has("seed"));
        let cli = parse(SPECS, &args(&["evolve", "--width=9"])).unwrap();
        assert_eq!(cli.flag("width", 8u32).unwrap(), 9);
    }

    #[test]
    fn defaults_apply_when_absent() {
        let cli = parse(SPECS, &args(&["evolve"])).unwrap();
        assert_eq!(cli.flag("width", 8u32).unwrap(), 8);
        assert_eq!(cli.flag_str("missing", "dflt"), "dflt");
    }

    #[test]
    fn empty_and_help_variants() {
        for argv in [
            vec![],
            args(&["help"]),
            args(&["--help"]),
            args(&["-h"]),
            args(&["evolve", "--help"]),
            args(&["evolve", "--width", "8", "-h"]),
        ] {
            assert_eq!(parse(SPECS, &argv).unwrap().command, "help");
        }
        assert!(!render_help("evoapprox", "test", SPECS).is_empty());
    }

    #[test]
    fn switch_rejects_inline_value() {
        let e = parse(SPECS, &args(&["evolve", "--quick=false"])).unwrap_err();
        assert_eq!(
            e,
            CliError::UnexpectedValue {
                flag: "--quick".into(),
                value: "false".into()
            }
        );
        assert!(e.to_string().contains("takes no value"));
    }

    #[test]
    fn unknown_command_rejected() {
        let e = parse(SPECS, &args(&["evolv"])).unwrap_err();
        assert!(matches!(e, CliError::UnknownCommand { .. }));
        assert!(e.to_string().contains("evolve"));
    }

    #[test]
    fn unknown_flag_rejected_with_suggestions() {
        let e = parse(SPECS, &args(&["evolve", "--widht", "8"])).unwrap_err();
        let CliError::UnknownFlag { flag, known, .. } = &e else {
            panic!("wrong error: {e:?}");
        };
        assert_eq!(flag, "--widht");
        assert!(known.contains(&"--width".to_string()));
    }

    #[test]
    fn missing_value_detected() {
        // at end of argv
        let e = parse(SPECS, &args(&["evolve", "--width"])).unwrap_err();
        assert_eq!(
            e,
            CliError::MissingValue {
                flag: "--width".into()
            }
        );
        // followed by another flag
        let e = parse(SPECS, &args(&["evolve", "--width", "--quick"])).unwrap_err();
        assert_eq!(
            e,
            CliError::MissingValue {
                flag: "--width".into()
            }
        );
    }

    #[test]
    fn negative_numbers_are_values() {
        let cli = parse(SPECS, &args(&["evolve", "--seed", "-5"])).unwrap();
        assert_eq!(cli.flag("seed", 0i64).unwrap(), -5);
    }

    #[test]
    fn bad_value_is_an_error_not_a_silent_default() {
        let cli = parse(SPECS, &args(&["evolve", "--width", "lots"])).unwrap();
        let e = cli.flag("width", 8u32).unwrap_err();
        assert_eq!(
            e,
            CliError::BadValue {
                flag: "--width".into(),
                value: "lots".into()
            }
        );
    }

    #[test]
    fn multi_token_command_wins_over_prefix() {
        // the two-token spec name matches, and its flags parse after it
        let cli = parse(SPECS, &args(&["lib", "compile", "--width", "16"])).unwrap();
        assert_eq!(cli.command, "lib compile");
        assert_eq!(cli.flag("width", 8u32).unwrap(), 16);
        // the bare prefix still resolves to the single-token spec
        let cli = parse(SPECS, &args(&["lib"])).unwrap();
        assert_eq!(cli.command, "lib");
        // a flag right after the prefix doesn't get mistaken for a
        // second command token (`lib` takes no flags → UnknownFlag)
        let e = parse(SPECS, &args(&["lib", "--width", "8"])).unwrap_err();
        assert!(matches!(e, CliError::UnknownFlag { .. }));
        // a stray second token that names no two-token spec is rejected
        // against the prefix command
        let e = parse(SPECS, &args(&["lib", "compil"])).unwrap_err();
        assert!(matches!(e, CliError::UnexpectedArg { .. }));
    }

    #[test]
    fn stray_positional_rejected() {
        let e = parse(SPECS, &args(&["evolve", "fast"])).unwrap_err();
        assert!(matches!(e, CliError::UnexpectedArg { .. }));
    }

    #[test]
    fn command_without_flags_rejects_any_flag() {
        let e = parse(SPECS, &args(&["info", "--width", "8"])).unwrap_err();
        assert!(e.to_string().contains("takes no flags"));
    }
}
