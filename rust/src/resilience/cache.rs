//! Shared resilience-evaluation cache.
//!
//! Every consumer of whole-network / per-layer accuracy numbers — the
//! `/v1/select` endpoint, the Fig. 4 campaign endpoint, the CLI analysis
//! commands and the `dse` subsystem — evaluates the *same* deterministic
//! pipeline: `(network, multiplier, layer scope, image count)` fully
//! determines the accuracy. This module gives them one process-wide memo
//! table so identical evaluations are computed once, replacing the ad-hoc
//! per-endpoint cache the server used to keep.
//!
//! Correctness under caching is free: the pipeline is deterministic, so a
//! cached value is bit-identical to a recomputed one — which is what keeps
//! the campaign/DSE "`--jobs 1` ≡ `--jobs N`" and "HTTP ≡ in-process"
//! byte-identity contracts intact whether the cache is cold or warm.
//! Lookups happen outside the lock; two racing misses compute twice and
//! agree.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

/// Which layers of the network carry the approximate multiplier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scope {
    /// Every conv layer (Table II / `/v1/select` style).
    Whole,
    /// A single conv layer, all others exact (Fig. 4 style).
    Layer(usize),
}

/// Key of one resilience evaluation. `multiplier` is the library id for a
/// uniform replacement, [`EvalKey::GOLDEN`] for the exact reference, or a
/// `+`-joined per-layer id list for a heterogeneous DSE assignment.
///
/// The evaluation split is identified by its **size only**: every current
/// consumer of a shared cache evaluates on the deterministic
/// `TestSet::synthetic(n)` split, where `n` fully determines the data.
/// Do NOT share one [`EvalCache`] across *different* splits of the same
/// size (e.g. a truncated exported test set and a synthetic one) — their
/// entries would silently alias. Use one cache per split, as the CLI
/// does (a fresh cache per `evoapprox dse` invocation).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EvalKey {
    /// Network name (`resnet8`, …).
    pub model: String,
    /// Multiplier identity (see type docs).
    pub multiplier: String,
    /// Layer scope of the replacement.
    pub scope: Scope,
    /// Evaluation-split size (see the type docs: the split must be the
    /// deterministic synthetic one, or at least unique per cache).
    pub images: usize,
}

impl EvalKey {
    /// Reserved multiplier name for the exact (golden) reference. All
    /// functionally exact multipliers share it: exactness is exhaustive
    /// zero error, so their accuracies are identical by construction.
    pub const GOLDEN: &'static str = "__golden__";

    /// Whole-network evaluation key.
    pub fn whole(model: &str, multiplier: &str, images: usize) -> EvalKey {
        EvalKey {
            model: model.to_string(),
            multiplier: multiplier.to_string(),
            scope: Scope::Whole,
            images,
        }
    }

    /// Single-layer evaluation key.
    pub fn layer(model: &str, multiplier: &str, layer: usize, images: usize) -> EvalKey {
        EvalKey {
            model: model.to_string(),
            multiplier: multiplier.to_string(),
            scope: Scope::Layer(layer),
            images,
        }
    }
}

#[derive(Default)]
struct Inner {
    map: Mutex<HashMap<EvalKey, f64>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Cloneable handle to the shared accuracy memo table.
#[derive(Clone, Default)]
pub struct EvalCache {
    inner: Arc<Inner>,
}

impl EvalCache {
    /// Empty cache.
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// Cached value, if present.
    pub fn get(&self, key: &EvalKey) -> Option<f64> {
        let hit = self.inner.map.lock().expect("eval cache poisoned").get(key).copied();
        match hit {
            Some(v) => {
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.inner.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a value (last write wins; racing writers agree by determinism).
    pub fn insert(&self, key: EvalKey, value: f64) {
        self.inner
            .map
            .lock()
            .expect("eval cache poisoned")
            .insert(key, value);
    }

    /// Fetch `key`, computing (outside the lock) and memoising on a miss.
    /// Errors are not cached — a transient failure must not poison the key.
    pub fn get_or_compute(
        &self,
        key: EvalKey,
        compute: impl FnOnce() -> Result<f64>,
    ) -> Result<f64> {
        if let Some(v) = self.get(&key) {
            return Ok(v);
        }
        let v = compute()?;
        self.insert(key, v);
        Ok(v)
    }

    /// Entries currently memoised.
    pub fn len(&self) -> usize {
        self.inner.map.lock().expect("eval cache poisoned").len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups answered from the table.
    pub fn hits(&self) -> u64 {
        self.inner.hits.load(Ordering::Relaxed)
    }

    /// Lookups that missed.
    pub fn misses(&self) -> u64 {
        self.inner.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn memoises_and_counts() {
        let cache = EvalCache::new();
        let key = EvalKey::whole("resnet8", "mul8u_0001", 32);
        assert!(cache.get(&key).is_none());
        assert_eq!(cache.misses(), 1);
        let mut computes = 0;
        for _ in 0..3 {
            let v = cache
                .get_or_compute(key.clone(), || {
                    computes += 1;
                    Ok(0.75)
                })
                .unwrap();
            assert_eq!(v, 0.75);
        }
        assert_eq!(computes, 1, "only the first lookup computes");
        assert_eq!(cache.len(), 1);
        assert!(cache.hits() >= 2);
    }

    #[test]
    fn scopes_and_images_are_distinct_keys() {
        let cache = EvalCache::new();
        cache.insert(EvalKey::whole("resnet8", "m", 32), 0.5);
        cache.insert(EvalKey::layer("resnet8", "m", 0, 32), 0.6);
        cache.insert(EvalKey::layer("resnet8", "m", 1, 32), 0.7);
        cache.insert(EvalKey::whole("resnet8", "m", 64), 0.8);
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.get(&EvalKey::whole("resnet8", "m", 32)), Some(0.5));
        assert_eq!(cache.get(&EvalKey::layer("resnet8", "m", 1, 32)), Some(0.7));
    }

    #[test]
    fn errors_are_not_cached() {
        let cache = EvalCache::new();
        let key = EvalKey::whole("resnet8", "m", 8);
        assert!(cache
            .get_or_compute(key.clone(), || Err(anyhow!("transient")))
            .is_err());
        assert_eq!(cache.len(), 0);
        let v = cache.get_or_compute(key, || Ok(0.9)).unwrap();
        assert_eq!(v, 0.9);
    }
}
