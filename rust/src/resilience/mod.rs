//! Resilience analysis of DNN accelerators (§IV): the paper's case study as
//! a reusable framework.
//!
//! A *campaign* sweeps approximate multipliers over networks/layers:
//! * [`per_layer_campaign`] — Fig. 4: one conv layer of ResNet-8 at a time
//!   is given the approximate multiplier's LUT (all other layers exact);
//!   reports per-layer accuracy drop vs. power drop.
//! * [`whole_network_campaign`] — Table II: every conv layer of every
//!   network uses the multiplier; reports accuracy per network next to the
//!   multiplier's circuit-level error metrics and relative power.
//!
//! LUTs come from [`lut`]: exhaustive bit-parallel simulation of the
//! multiplier netlist (the TFApprox ingestion path, done in Rust).
//!
//! [`cache`] is the shared evaluation memo table: every consumer of
//! accuracy numbers — campaigns, `/v1/select`, the `dse` subsystem — keys
//! its evaluations by `(network, multiplier, layer scope, images)` so
//! identical grid points are computed once process-wide.

pub mod cache;
pub mod campaign;
pub mod lut;

pub use cache::{EvalCache, EvalKey, Scope};
pub use campaign::{
    per_layer_campaign, per_layer_campaign_cached, per_layer_campaign_progress,
    standard_multipliers, whole_network_campaign, Fig4Point, Fig4Report, MultiplierSummary,
    Table2Report, Table2Row,
};
pub use lut::{lut_for_entry, lut_from_netlist};
