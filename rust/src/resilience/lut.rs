//! Netlist → product-LUT construction (the TFApprox ingestion path).
//!
//! An 8×8 unsigned multiplier netlist is exhaustively simulated over all
//! 2¹⁶ operand pairs (bit-parallel, ~1 ms) and its outputs become the
//! 256×256 i32 table the AOT graphs gather from. Row-major layout:
//! `lut[a * 256 + w]` — operand A is the activation code, W the weight
//! code, matching `python/compile/kernels/ref.py`.

use anyhow::{bail, Result};

use crate::circuit::netlist::Netlist;
use crate::circuit::simulator::eval_exhaustive_u64;
use crate::library::entry::Entry;
use crate::runtime::LUT_LEN;

/// Build the LUT of an 8-bit multiplier netlist.
///
/// Input convention (see `circuit::generators`): inputs `0..8` = operand A,
/// `8..16` = operand B; the exhaustive enumeration index is `a | b << 8`,
/// i.e. B is the *major* axis — the LUT wants A major, so indices are
/// transposed here.
pub fn lut_from_netlist(n: &Netlist) -> Result<Vec<i32>> {
    if n.n_inputs != 16 || n.n_outputs() != 16 {
        bail!(
            "LUT construction needs an 8×8→16 multiplier (got {}→{})",
            n.n_inputs,
            n.n_outputs()
        );
    }
    let table = eval_exhaustive_u64(n);
    let mut lut = vec![0i32; LUT_LEN];
    for b in 0..256usize {
        for a in 0..256usize {
            // enumeration index: a | b<<8 ; LUT index: a*256 + b
            lut[a * 256 + b] = table[(b << 8) | a] as i32;
        }
    }
    Ok(lut)
}

/// Build the LUT of a library entry (must be a `mul8u`).
pub fn lut_for_entry(e: &Entry) -> Result<Vec<i32>> {
    lut_from_netlist(&e.netlist)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::baselines::{bam_multiplier, truncated_multiplier};
    use crate::circuit::generators::wallace_multiplier;
    use crate::runtime::exact_lut;

    #[test]
    fn exact_multiplier_gives_exact_lut() {
        let lut = lut_from_netlist(&wallace_multiplier(8)).unwrap();
        assert_eq!(lut, exact_lut());
    }

    #[test]
    fn truncated_multiplier_lut_semantics() {
        let lut = lut_from_netlist(&truncated_multiplier(8, 7)).unwrap();
        for a in [0usize, 3, 77, 254, 255] {
            for w in [0usize, 9, 128, 255] {
                let expect = ((a & !1) * (w & !1)) as i32;
                assert_eq!(lut[a * 256 + w], expect, "a={a} w={w}");
            }
        }
    }

    #[test]
    fn bam_lut_underestimates() {
        let lut = lut_from_netlist(&bam_multiplier(8, 1, 6)).unwrap();
        let exact = exact_lut();
        assert!(lut.iter().zip(&exact).all(|(l, e)| l <= e));
        assert!(lut.iter().zip(&exact).any(|(l, e)| l < e));
    }

    #[test]
    fn rejects_wrong_interface() {
        assert!(lut_from_netlist(&wallace_multiplier(4)).is_err());
    }
}
