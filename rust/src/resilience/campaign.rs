//! Campaign drivers: Fig. 4 (per-layer) and Table II (whole-network)
//! sweeps, scheduled through the coordinator.
//!
//! Both campaigns fan their evaluation grids — (multiplier × layer) for
//! Fig. 4, (multiplier × network) for Table II — across the
//! `cgp::campaign` job pool. The pool's submission-order-merge contract
//! makes the reports byte-identical for any worker count: on the native
//! backend jobs execute truly in parallel, on PJRT they serialise through
//! the executor actor, and either way the points come back in grid order.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::accel::PowerModel;
use crate::cgp::campaign::map_parallel;
use crate::cgp::metrics::SELECTION_METRICS;
use crate::circuit::baselines::table2_baselines;
use crate::circuit::cost::{CircuitCost, CostModel};
use crate::circuit::generators::wallace_multiplier;
use crate::circuit::verify::ArithFn;
use crate::coordinator::{Coordinator, KernelKind};
use crate::library::entry::{Entry, Origin};
use crate::library::LibrarySource;
use crate::runtime::manifest::TestSet;
use crate::runtime::{broadcast_lut, exact_lut, LUT_LEN};

use crate::cgp::campaign::map_parallel_progress;
use crate::obs::progress::Progress;
use crate::obs::trace;

use super::cache::{EvalCache, EvalKey};
use super::lut::lut_for_entry;

/// A multiplier under analysis: its LUT plus reporting metadata.
#[derive(Debug, Clone)]
pub struct MultiplierSummary {
    /// Library id (`mul8u_XXXX`) or baseline label.
    pub id: String,
    /// Human label (Table II first column).
    pub label: String,
    /// Provenance of the entry (seed / evolved / truncated / BAM).
    pub origin: Origin,
    /// Whether this is a functionally exact multiplier (the paper's
    /// golden reference) — judged by provenance and exhaustive zero error,
    /// never by floating-point power coincidence.
    pub is_exact: bool,
    /// Relative power vs the exact multiplier [%].
    pub rel_power_pct: f64,
    /// Table-II error columns [%].
    pub mae_pct: f64,
    /// WCE [%].
    pub wce_pct: f64,
    /// MRE [%].
    pub mre_pct: f64,
    /// WCRE [%].
    pub wcre_pct: f64,
    /// ER [%].
    pub er_pct: f64,
    /// Provable WCE ceiling [% of max output] from static analysis — a
    /// sound bound the sampled `wce_pct` can never legitimately exceed.
    pub wce_bound_pct: f64,
    /// Whether static analysis proved the circuit functionally exact.
    pub exact_proven: bool,
    /// The 65536-entry product table.
    pub lut: Vec<i32>,
    /// Circuit power characterisation (for per-layer power accounting).
    pub cost: CircuitCost,
}

impl MultiplierSummary {
    /// Build from a library entry, with `exact_cost` as the 100 % reference.
    pub fn from_entry(e: &Entry, exact_cost: &CircuitCost) -> Result<MultiplierSummary> {
        Ok(MultiplierSummary {
            id: e.id.clone(),
            label: match &e.origin {
                Origin::Evolved { .. } => e.id.clone(),
                other => other.label(),
            },
            origin: e.origin.clone(),
            is_exact: matches!(e.origin, Origin::Seed(_))
                || (e.metrics.exhaustive && e.metrics.er == 0.0),
            rel_power_pct: e.cost.relative_power(exact_cost),
            mae_pct: e.rel.mae_pct,
            wce_pct: e.rel.wce_pct,
            mre_pct: e.rel.mre_pct,
            wcre_pct: e.rel.wcre_pct,
            er_pct: e.rel.er_pct,
            wce_bound_pct: {
                let max_out = (e.f.n_outputs() as f64).exp2() - 1.0;
                e.bounds.wce_bound / max_out * 100.0
            },
            exact_proven: e.bounds.exact_proven,
            lut: lut_for_entry(e)?,
            cost: e.cost,
        })
    }
}

/// The standard multiplier roster shared by the CLI analysis commands and
/// the HTTP server: the exact 8-bit reference first, then the §IV
/// Pareto-diverse selection from `lib` (falling back to the Table II
/// baseline set when `lib` is `None` or its selection comes back empty),
/// truncated to at most `limit` approximate entries.
///
/// Determinism matters here: for a fixed library the roster is a pure
/// function of `(k_per_metric, limit)`, which is what lets the server's
/// campaign endpoint reproduce an in-process campaign byte-for-byte.
pub fn standard_multipliers(
    lib: Option<&LibrarySource>,
    k_per_metric: usize,
    limit: usize,
) -> Result<Vec<MultiplierSummary>> {
    let model = CostModel::default();
    let f = ArithFn::Mul { w: 8 };
    let exact = Entry::characterise(
        wallace_multiplier(8),
        f,
        &model,
        Origin::Seed("wallace".into()),
    );
    let mut sel: Vec<Entry> = Vec::new();
    if let Some(lib) = lib {
        sel = lib.select_diverse(f, &SELECTION_METRICS, k_per_metric);
    }
    if sel.is_empty() {
        // pre-campaign fallback: the paper's published baseline rows
        for n in table2_baselines() {
            let origin = Origin::from_baseline_name(&n.name);
            sel.push(Entry::characterise(n, f, &model, origin));
        }
    }
    sel.truncate(limit);
    let mut mults = vec![MultiplierSummary::from_entry(&exact, &exact.cost)?];
    for e in &sel {
        mults.push(MultiplierSummary::from_entry(e, &exact.cost)?);
    }
    Ok(mults)
}

/// One Fig. 4 point: (multiplier, layer) → accuracy & power drop.
#[derive(Debug, Clone)]
pub struct Fig4Point {
    /// Multiplier id.
    pub multiplier: String,
    /// Layer index (execution order).
    pub layer: usize,
    /// Paper-style layer label (`S=3 R=1 C=1` / `stem`).
    pub layer_label: String,
    /// Fraction of the network's multiplications in this layer.
    pub layer_fraction: f64,
    /// Classification accuracy with only this layer approximated.
    pub accuracy: f64,
    /// Accuracy drop vs the golden baseline (positive = worse).
    pub accuracy_drop: f64,
    /// Multiplier-power drop of the whole accelerator [%].
    pub power_drop_pct: f64,
}

/// Fig. 4 output: reference accuracy + all points.
#[derive(Debug, Clone)]
pub struct Fig4Report {
    /// Model analysed (paper: ResNet-8).
    pub model: String,
    /// Golden (exact-LUT) accuracy.
    pub reference_accuracy: f64,
    /// Whether per-layer power used a real exact-multiplier circuit as the
    /// 100 % reference (`true`), or fell back to interpolating from the
    /// summaries' pre-computed relative powers because no exact entry was
    /// in the sweep (`false`).
    pub power_reference_exact: bool,
    /// All (multiplier × layer) points.
    pub points: Vec<Fig4Point>,
}

/// Route one evaluation through the optional shared cache.
fn run_cached(
    cache: Option<&EvalCache>,
    key: EvalKey,
    compute: impl FnOnce() -> Result<f64>,
) -> Result<f64> {
    match cache {
        Some(c) => c.get_or_compute(key, compute),
        None => compute(),
    }
}

/// Fig. 4: approximate ONE conv layer at a time (§IV). The
/// (multiplier × layer) grid is evaluated on `jobs` pool workers; results
/// are merged in submission order, so the report is byte-identical for any
/// `jobs` value.
pub fn per_layer_campaign(
    coord: &Coordinator,
    model: &str,
    multipliers: &[MultiplierSummary],
    testset: &TestSet,
    kernel: KernelKind,
    jobs: usize,
) -> Result<Fig4Report> {
    per_layer_campaign_cached(coord, model, multipliers, testset, kernel, jobs, None)
}

/// [`per_layer_campaign`] with an optional shared [`EvalCache`]: every
/// `(multiplier, layer)` accuracy — and the golden reference — is looked
/// up under its [`EvalKey`] first and memoised after computing.
/// The pipeline is deterministic, so a warm cache returns exactly the
/// values a cold run computes and the byte-identity contract is
/// unaffected; what changes is that `/v1/select`, campaign jobs and DSE
/// runs stop re-evaluating identical grid points.
pub fn per_layer_campaign_cached(
    coord: &Coordinator,
    model: &str,
    multipliers: &[MultiplierSummary],
    testset: &TestSet,
    kernel: KernelKind,
    jobs: usize,
    cache: Option<&EvalCache>,
) -> Result<Fig4Report> {
    per_layer_campaign_progress(
        coord,
        model,
        multipliers,
        testset,
        kernel,
        jobs,
        cache,
        None,
        "layer-campaign",
    )
}

/// [`per_layer_campaign_cached`] with an optional [`Progress`] handle:
/// enters `stage` (the DSE driver names it `probe`, campaign jobs
/// `layer-campaign`) sized to one golden-reference tick plus one tick
/// per `(multiplier, layer)` grid point, delivered in pool order.
/// Progress and the `campaign` trace spans are pure side channels — the
/// report is byte-identical with them on or off (tested).
#[allow(clippy::too_many_arguments)]
pub fn per_layer_campaign_progress(
    coord: &Coordinator,
    model: &str,
    multipliers: &[MultiplierSummary],
    testset: &TestSet,
    kernel: KernelKind,
    jobs: usize,
    cache: Option<&EvalCache>,
    progress: Option<&Progress>,
    stage: &str,
) -> Result<Fig4Report> {
    let _span = trace::span_arg("campaign", "per-layer", "model", || model.to_string());
    let meta = coord
        .manifest()
        .model(model)
        .ok_or_else(|| anyhow!("unknown model `{model}`"))?
        .clone();
    let n_layers = meta.n_conv_layers;
    let pm = PowerModel::from_manifest(&meta);
    let exact = exact_lut();
    let images = Arc::new(testset.images.clone());
    if let Some(p) = progress {
        // golden reference + the full (multiplier × layer) grid
        p.set_stage(stage, (multipliers.len() * n_layers) as u64 + 1);
    }
    let golden = {
        let _s = trace::span("campaign", "golden-reference");
        run_cached(cache, EvalKey::whole(model, EvalKey::GOLDEN, testset.n), || {
            coord.accuracy(
                model,
                kernel,
                images.clone(),
                &testset.labels,
                Arc::new(broadcast_lut(&exact, n_layers)),
            )
        })?
    };
    if let Some(p) = progress {
        p.tick();
    }
    // The 100 % power reference is the exact multiplier itself, identified
    // by provenance — NOT by a floating-point `rel_power == 100` match,
    // which silently picks nothing (or a coincidental entry) when the
    // exact row is absent.
    let exact_cost = multipliers.iter().find(|m| m.is_exact).map(|m| m.cost);
    let grid: Vec<(usize, usize)> = (0..multipliers.len())
        .flat_map(|mi| (0..n_layers).map(move |layer| (mi, layer)))
        .collect();
    let accuracies = map_parallel_progress(grid.clone(), jobs.max(1), progress, |_, (mi, layer), _scratch| {
        let _s = trace::span("campaign", "layer-eval");
        let m = &multipliers[mi];
        // a functionally exact multiplier in any single layer IS the
        // golden network — share the golden cache entry instead of a
        // per-layer one
        let key = if m.is_exact {
            EvalKey::whole(model, EvalKey::GOLDEN, testset.n)
        } else {
            EvalKey::layer(model, &m.id, layer, testset.n)
        };
        run_cached(cache, key, || {
            let mut luts = broadcast_lut(&exact, n_layers);
            luts[layer * LUT_LEN..(layer + 1) * LUT_LEN].copy_from_slice(&m.lut);
            coord.accuracy(
                model,
                kernel,
                images.clone(),
                &testset.labels,
                Arc::new(luts),
            )
        })
    });
    let mut points = Vec::with_capacity(grid.len());
    for ((mi, layer), acc) in grid.into_iter().zip(accuracies) {
        let m = &multipliers[mi];
        let acc = acc?;
        // power: whole-accelerator multiplier power with this one layer
        // approximated; the reference cost is the exact multiplier's.
        let power_pct = match &exact_cost {
            Some(e) => pm.relative_power(e, &m.cost, Some(layer)),
            None => {
                let f = pm.layer_fraction(layer);
                (1.0 - f) * 100.0 + f * m.rel_power_pct
            }
        };
        points.push(Fig4Point {
            multiplier: m.id.clone(),
            layer,
            layer_label: crate::accel::layer_label(&meta.layers[layer]),
            layer_fraction: pm.layer_fraction(layer),
            accuracy: acc,
            accuracy_drop: golden - acc,
            power_drop_pct: 100.0 - power_pct,
        });
    }
    Ok(Fig4Report {
        model: model.to_string(),
        reference_accuracy: golden,
        power_reference_exact: exact_cost.is_some(),
        points,
    })
}

/// One Table II row: a multiplier's metrics + accuracy on every network.
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// Multiplier metadata (errors, power).
    pub multiplier: MultiplierSummary,
    /// `(model name, accuracy)` per network, in manifest order.
    pub accuracies: Vec<(String, f64)>,
}

/// Table II output.
#[derive(Debug, Clone)]
pub struct Table2Report {
    /// Golden accuracy per network (the "8 bit (exact)" row).
    pub exact_row: Vec<(String, f64)>,
    /// One row per multiplier.
    pub rows: Vec<Table2Row>,
}

/// Table II: approximate ALL conv layers of every network (§IV). The
/// (multiplier × network) grid — including the exact reference row — runs
/// on `jobs` pool workers with submission-order merging (`jobs = 1` and
/// `jobs = N` produce byte-identical reports).
pub fn whole_network_campaign(
    coord: &Coordinator,
    models: &[String],
    multipliers: &[MultiplierSummary],
    testset: &TestSet,
    kernel: KernelKind,
    jobs: usize,
) -> Result<Table2Report> {
    let images = Arc::new(testset.images.clone());
    let exact = exact_lut();
    let mut layers_per_model = Vec::with_capacity(models.len());
    for name in models {
        let meta = coord
            .manifest()
            .model(name)
            .ok_or_else(|| anyhow!("unknown model `{name}`"))?;
        layers_per_model.push(meta.n_conv_layers);
    }
    // grid row -1 = the exact baseline, rows 0.. = the multipliers
    let grid: Vec<(Option<usize>, usize)> = std::iter::once(None)
        .chain((0..multipliers.len()).map(Some))
        .flat_map(|mi| (0..models.len()).map(move |m| (mi, m)))
        .collect();
    let accuracies = map_parallel(grid.clone(), jobs.max(1), |_, (mi, mdl), _scratch| {
        let n_layers = layers_per_model[mdl];
        let lut = match mi {
            None => &exact,
            Some(i) => &multipliers[i].lut,
        };
        coord.accuracy(
            &models[mdl],
            kernel,
            images.clone(),
            &testset.labels,
            Arc::new(broadcast_lut(lut, n_layers)),
        )
    });
    let mut exact_row = Vec::with_capacity(models.len());
    let mut rows: Vec<Table2Row> = multipliers
        .iter()
        .map(|m| Table2Row {
            multiplier: m.clone(),
            accuracies: Vec::with_capacity(models.len()),
        })
        .collect();
    for ((mi, mdl), acc) in grid.into_iter().zip(accuracies) {
        let acc = acc?;
        match mi {
            None => exact_row.push((models[mdl].clone(), acc)),
            Some(i) => rows[i].accuracies.push((models[mdl].clone(), acc)),
        }
    }
    Ok(Table2Report { exact_row, rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::baselines::bam_multiplier;
    use crate::circuit::cost::CostModel;
    use crate::circuit::generators::wallace_multiplier;
    use crate::circuit::verify::ArithFn;
    use crate::library::entry::{Entry, Origin};

    #[test]
    fn summary_from_entry() {
        let model = CostModel::default();
        let f = ArithFn::Mul { w: 8 };
        let exact = Entry::characterise(
            wallace_multiplier(8),
            f,
            &model,
            Origin::Seed("wallace".into()),
        );
        let bam = Entry::characterise(
            bam_multiplier(8, 0, 6),
            f,
            &model,
            Origin::Bam { h: 0, v: 6 },
        );
        let s = MultiplierSummary::from_entry(&bam, &exact.cost).unwrap();
        assert!(s.rel_power_pct < 100.0);
        assert!(s.mae_pct > 0.0);
        assert_eq!(s.lut.len(), LUT_LEN);
        assert_eq!(s.label, "BAM h=0 v=6");
        assert!(!s.is_exact);
        let se = MultiplierSummary::from_entry(&exact, &exact.cost).unwrap();
        assert!((se.rel_power_pct - 100.0).abs() < 1e-9);
        assert_eq!(se.lut, crate::runtime::exact_lut());
        assert!(se.is_exact);
    }

    #[test]
    fn standard_multipliers_roster() {
        // no library → exact reference + the baseline rows, truncated
        let mults = standard_multipliers(None, 10, 4).unwrap();
        assert_eq!(mults.len(), 5);
        assert!(mults[0].is_exact);
        assert!(mults[1..].iter().all(|m| !m.is_exact));
        // library-backed roster is a pure function of its inputs
        let lib = LibrarySource::baseline();
        let a = standard_multipliers(Some(&lib), 10, 6).unwrap();
        let b = standard_multipliers(Some(&lib), 10, 6).unwrap();
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.id == y.id));
        assert!(a.len() <= 1 + 6);
    }

    /// A 100 % relative power coincidence must NOT be mistaken for the
    /// exact reference — exactness is judged by provenance/function only.
    #[test]
    fn power_coincidence_is_not_exactness() {
        let model = CostModel::default();
        let f = ArithFn::Mul { w: 8 };
        let bam = Entry::characterise(
            bam_multiplier(8, 0, 6),
            f,
            &model,
            Origin::Bam { h: 0, v: 6 },
        );
        // reference the BAM against its own cost → rel_power == 100 %
        let s = MultiplierSummary::from_entry(&bam, &bam.cost).unwrap();
        assert!((s.rel_power_pct - 100.0).abs() < 1e-9);
        assert!(!s.is_exact);
    }
}
