//! Campaign drivers: Fig. 4 (per-layer) and Table II (whole-network)
//! sweeps, scheduled through the coordinator.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::accel::PowerModel;
use crate::circuit::cost::CircuitCost;
use crate::coordinator::{Coordinator, KernelKind};
use crate::library::entry::Entry;
use crate::runtime::manifest::TestSet;
use crate::runtime::{broadcast_lut, exact_lut, LUT_LEN};

use super::lut::lut_for_entry;

/// A multiplier under analysis: its LUT plus reporting metadata.
#[derive(Debug, Clone)]
pub struct MultiplierSummary {
    /// Library id (`mul8u_XXXX`) or baseline label.
    pub id: String,
    /// Human label (Table II first column).
    pub label: String,
    /// Relative power vs the exact multiplier [%].
    pub rel_power_pct: f64,
    /// Table-II error columns [%].
    pub mae_pct: f64,
    /// WCE [%].
    pub wce_pct: f64,
    /// MRE [%].
    pub mre_pct: f64,
    /// WCRE [%].
    pub wcre_pct: f64,
    /// ER [%].
    pub er_pct: f64,
    /// The 65536-entry product table.
    pub lut: Vec<i32>,
    /// Circuit power characterisation (for per-layer power accounting).
    pub cost: CircuitCost,
}

impl MultiplierSummary {
    /// Build from a library entry, with `exact_cost` as the 100 % reference.
    pub fn from_entry(e: &Entry, exact_cost: &CircuitCost) -> Result<MultiplierSummary> {
        Ok(MultiplierSummary {
            id: e.id.clone(),
            label: match &e.origin {
                crate::library::entry::Origin::Evolved { .. } => e.id.clone(),
                other => other.label(),
            },
            rel_power_pct: e.cost.relative_power(exact_cost),
            mae_pct: e.rel.mae_pct,
            wce_pct: e.rel.wce_pct,
            mre_pct: e.rel.mre_pct,
            wcre_pct: e.rel.wcre_pct,
            er_pct: e.rel.er_pct,
            lut: lut_for_entry(e)?,
            cost: e.cost,
        })
    }
}

/// One Fig. 4 point: (multiplier, layer) → accuracy & power drop.
#[derive(Debug, Clone)]
pub struct Fig4Point {
    /// Multiplier id.
    pub multiplier: String,
    /// Layer index (execution order).
    pub layer: usize,
    /// Paper-style layer label (`S=3 R=1 C=1` / `stem`).
    pub layer_label: String,
    /// Fraction of the network's multiplications in this layer.
    pub layer_fraction: f64,
    /// Classification accuracy with only this layer approximated.
    pub accuracy: f64,
    /// Accuracy drop vs the golden baseline (positive = worse).
    pub accuracy_drop: f64,
    /// Multiplier-power drop of the whole accelerator [%].
    pub power_drop_pct: f64,
}

/// Fig. 4 output: reference accuracy + all points.
#[derive(Debug, Clone)]
pub struct Fig4Report {
    /// Model analysed (paper: ResNet-8).
    pub model: String,
    /// Golden (exact-LUT) accuracy.
    pub reference_accuracy: f64,
    /// All (multiplier × layer) points.
    pub points: Vec<Fig4Point>,
}

/// Fig. 4: approximate ONE conv layer at a time (§IV).
pub fn per_layer_campaign(
    coord: &Coordinator,
    model: &str,
    multipliers: &[MultiplierSummary],
    testset: &TestSet,
    kernel: KernelKind,
) -> Result<Fig4Report> {
    let meta = coord
        .manifest()
        .model(model)
        .ok_or_else(|| anyhow!("unknown model `{model}`"))?
        .clone();
    let n_layers = meta.n_conv_layers;
    let pm = PowerModel::from_manifest(&meta);
    let exact = exact_lut();
    let images = Arc::new(testset.images.clone());
    let golden = coord.accuracy(
        model,
        kernel,
        images.clone(),
        &testset.labels,
        Arc::new(broadcast_lut(&exact, n_layers)),
    )?;
    let exact_cost = multipliers
        .iter()
        .find(|m| (m.rel_power_pct - 100.0).abs() < 1e-6)
        .map(|m| m.cost);
    let mut points = Vec::new();
    for m in multipliers {
        for layer in 0..n_layers {
            let mut luts = broadcast_lut(&exact, n_layers);
            luts[layer * LUT_LEN..(layer + 1) * LUT_LEN].copy_from_slice(&m.lut);
            let acc = coord.accuracy(
                model,
                kernel,
                images.clone(),
                &testset.labels,
                Arc::new(luts),
            )?;
            // power: whole-accelerator multiplier power with this one layer
            // approximated; the reference cost is the exact multiplier's.
            let power_pct = match &exact_cost {
                Some(e) => pm.relative_power(e, &m.cost, Some(layer)),
                None => {
                    let f = pm.layer_fraction(layer);
                    (1.0 - f) * 100.0 + f * m.rel_power_pct
                }
            };
            points.push(Fig4Point {
                multiplier: m.id.clone(),
                layer,
                layer_label: crate::accel::layer_label(&meta.layers[layer]),
                layer_fraction: pm.layer_fraction(layer),
                accuracy: acc,
                accuracy_drop: golden - acc,
                power_drop_pct: 100.0 - power_pct,
            });
        }
    }
    Ok(Fig4Report {
        model: model.to_string(),
        reference_accuracy: golden,
        points,
    })
}

/// One Table II row: a multiplier's metrics + accuracy on every network.
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// Multiplier metadata (errors, power).
    pub multiplier: MultiplierSummary,
    /// `(model name, accuracy)` per network, in manifest order.
    pub accuracies: Vec<(String, f64)>,
}

/// Table II output.
#[derive(Debug, Clone)]
pub struct Table2Report {
    /// Golden accuracy per network (the "8 bit (exact)" row).
    pub exact_row: Vec<(String, f64)>,
    /// One row per multiplier.
    pub rows: Vec<Table2Row>,
}

/// Table II: approximate ALL conv layers of every network (§IV).
pub fn whole_network_campaign(
    coord: &Coordinator,
    models: &[String],
    multipliers: &[MultiplierSummary],
    testset: &TestSet,
    kernel: KernelKind,
) -> Result<Table2Report> {
    let images = Arc::new(testset.images.clone());
    let exact = exact_lut();
    let mut exact_row = Vec::new();
    let mut luts_per_model = Vec::new();
    for name in models {
        let meta = coord
            .manifest()
            .model(name)
            .ok_or_else(|| anyhow!("unknown model `{name}`"))?;
        let n_layers = meta.n_conv_layers;
        luts_per_model.push(n_layers);
        let acc = coord.accuracy(
            name,
            kernel,
            images.clone(),
            &testset.labels,
            Arc::new(broadcast_lut(&exact, n_layers)),
        )?;
        exact_row.push((name.clone(), acc));
    }
    let mut rows = Vec::new();
    for m in multipliers {
        let mut accuracies = Vec::new();
        for (name, &n_layers) in models.iter().zip(&luts_per_model) {
            let acc = coord.accuracy(
                name,
                kernel,
                images.clone(),
                &testset.labels,
                Arc::new(broadcast_lut(&m.lut, n_layers)),
            )?;
            accuracies.push((name.clone(), acc));
        }
        rows.push(Table2Row {
            multiplier: m.clone(),
            accuracies,
        });
    }
    Ok(Table2Report { exact_row, rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::baselines::bam_multiplier;
    use crate::circuit::cost::CostModel;
    use crate::circuit::generators::wallace_multiplier;
    use crate::circuit::verify::ArithFn;
    use crate::library::entry::{Entry, Origin};

    #[test]
    fn summary_from_entry() {
        let model = CostModel::default();
        let f = ArithFn::Mul { w: 8 };
        let exact = Entry::characterise(
            wallace_multiplier(8),
            f,
            &model,
            Origin::Seed("wallace".into()),
        );
        let bam = Entry::characterise(
            bam_multiplier(8, 0, 6),
            f,
            &model,
            Origin::Bam { h: 0, v: 6 },
        );
        let s = MultiplierSummary::from_entry(&bam, &exact.cost).unwrap();
        assert!(s.rel_power_pct < 100.0);
        assert!(s.mae_pct > 0.0);
        assert_eq!(s.lut.len(), LUT_LEN);
        assert_eq!(s.label, "BAM h=0 v=6");
        let se = MultiplierSummary::from_entry(&exact, &exact.cost).unwrap();
        assert!((se.rel_power_pct - 100.0).abs() < 1e-9);
        assert_eq!(se.lut, crate::runtime::exact_lut());
    }
}
