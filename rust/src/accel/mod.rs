//! DNN hardware-accelerator model (§IV): ResNet architecture descriptions,
//! per-layer multiplier census, and the power model that converts a
//! multiplier's circuit-level power into the "relative power of the
//! convolutional layers' multipliers" the paper reports.
//!
//! The Rust side re-derives the architecture independently of the Python
//! manifest (`runtime::manifest`) and the two are cross-checked in tests —
//! catching drift between the build path and the analysis path.

use crate::circuit::cost::CircuitCost;
use crate::runtime::manifest::{LayerMeta, ModelMeta};

/// The ResNet depths of the paper's Table II.
pub const PAPER_DEPTHS: [u32; 8] = [8, 14, 20, 26, 32, 38, 44, 50];

/// One conv layer of a ResNet spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvLayer {
    /// Stage (0 = stem).
    pub stage: u32,
    /// Block within the stage (1-based).
    pub block: u32,
    /// Conv within the block (1-based).
    pub conv: u32,
    /// Input channels.
    pub cin: u32,
    /// Output channels.
    pub cout: u32,
    /// Spatial stride.
    pub stride: u32,
}

/// Architecture description of one 6n+2 ResNet (mirrors
/// `python/compile/model.py::resnet_spec`).
#[derive(Debug, Clone)]
pub struct ResNetSpec {
    /// Network depth (6n+2).
    pub depth: u32,
    /// Base width.
    pub width: u32,
    /// Conv layers in execution order.
    pub layers: Vec<ConvLayer>,
}

impl ResNetSpec {
    /// Build the spec for `depth = 6n+2` with base `width`.
    pub fn new(depth: u32, width: u32) -> ResNetSpec {
        assert_eq!((depth - 2) % 6, 0, "depth must be 6n+2");
        let n = (depth - 2) / 6;
        let mut layers = vec![ConvLayer {
            stage: 0,
            block: 1,
            conv: 1,
            cin: 3,
            cout: width,
            stride: 1,
        }];
        let mut cin = width;
        for stage in 0..3u32 {
            let cout = width * [1, 2, 4][stage as usize];
            for block in 0..n {
                let stride = if stage > 0 && block == 0 { 2 } else { 1 };
                layers.push(ConvLayer {
                    stage: stage + 1,
                    block: block + 1,
                    conv: 1,
                    cin,
                    cout,
                    stride,
                });
                layers.push(ConvLayer {
                    stage: stage + 1,
                    block: block + 1,
                    conv: 2,
                    cin: cout,
                    cout,
                    stride: 1,
                });
                cin = cout;
            }
        }
        ResNetSpec {
            depth,
            width,
            layers,
        }
    }

    /// Multiplications per image for every conv layer at `image_size`
    /// (3×3 kernels, SAME padding — mirrors
    /// `model.py::layer_mult_counts`).
    pub fn mult_counts(&self, image_size: u32) -> Vec<u64> {
        let mut size = image_size as u64;
        let mut out = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            if i > 0 && l.stride == 2 {
                size /= 2;
            }
            out.push(size * size * 9 * l.cin as u64 * l.cout as u64);
        }
        out
    }

    /// Total multiplications per inference.
    pub fn total_mults(&self, image_size: u32) -> u64 {
        self.mult_counts(image_size).iter().sum()
    }
}

/// Power model: energy of all conv multiplications, given a multiplier's
/// circuit characterisation. Absolute energy uses the cost model's per-
/// multiplication energy (power × delay would be one convention; following
/// the paper we only ever *report ratios*, so any per-multiplication
/// constant cancels).
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Multiplications per image per layer.
    pub layer_mults: Vec<u64>,
}

impl PowerModel {
    /// From a Rust-side spec.
    pub fn from_spec(spec: &ResNetSpec, image_size: u32) -> PowerModel {
        PowerModel {
            layer_mults: spec.mult_counts(image_size),
        }
    }

    /// From the build manifest (cross-checked against `from_spec` in tests).
    pub fn from_manifest(model: &ModelMeta) -> PowerModel {
        PowerModel {
            layer_mults: model.layers.iter().map(|l| l.n_mults).collect(),
        }
    }

    /// Total multiplications.
    pub fn total(&self) -> u64 {
        self.layer_mults.iter().sum()
    }

    /// Fraction of all multiplications residing in `layer` (Fig. 4's
    /// per-layer percentages).
    pub fn layer_fraction(&self, layer: usize) -> f64 {
        self.layer_mults[layer] as f64 / self.total().max(1) as f64
    }

    /// Relative power [%] of the multipliers when `approx` replaces
    /// `exact` in the given layers (`None` ⇒ all layers — Table II;
    /// `Some(i)` ⇒ only layer `i` — Fig. 4).
    pub fn relative_power(
        &self,
        exact: &CircuitCost,
        approx: &CircuitCost,
        layer: Option<usize>,
    ) -> f64 {
        if exact.power_uw <= 0.0 {
            return 0.0;
        }
        let ratio = approx.power_uw / exact.power_uw;
        match layer {
            None => 100.0 * ratio,
            Some(i) => {
                let f = self.layer_fraction(i);
                100.0 * ((1.0 - f) + f * ratio)
            }
        }
    }
}

/// Table-row metadata for Fig. 4: label a layer the way the paper does.
pub fn layer_label(l: &LayerMeta) -> String {
    if l.stage == 0 {
        "stem".to_string()
    } else {
        format!("S={} R={} C={}", l.stage, l.block, l.conv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_layer_counts() {
        for depth in PAPER_DEPTHS {
            let spec = ResNetSpec::new(depth, 8);
            let n = (depth - 2) / 6;
            assert_eq!(spec.layers.len() as u32, 6 * n + 1, "depth {depth}");
        }
    }

    #[test]
    fn resnet8_has_seven_convs_and_stage3_peak() {
        let spec = ResNetSpec::new(8, 8);
        assert_eq!(spec.layers.len(), 7);
        let counts = spec.mult_counts(16);
        let total: u64 = counts.iter().sum();
        // stem is the clear minimum (paper: 2.09 % at full scale)
        assert_eq!(counts[0], *counts.iter().min().unwrap());
        // a stage-3 layer carries the maximum count
        let max_i = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap()
            .0;
        assert_eq!(spec.layers[max_i].stage, 3);
        assert!(total > 0);
    }

    #[test]
    fn deeper_nets_multiply_more() {
        let mut prev = 0;
        for depth in PAPER_DEPTHS {
            let t = ResNetSpec::new(depth, 8).total_mults(16);
            assert!(t > prev, "depth {depth}");
            prev = t;
        }
    }

    #[test]
    fn per_layer_power_interpolates() {
        let spec = ResNetSpec::new(8, 8);
        let pm = PowerModel::from_spec(&spec, 16);
        let exact = CircuitCost {
            gates: 100,
            area_um2: 100.0,
            delay_ps: 100.0,
            leakage_uw: 1.0,
            dynamic_uw: 9.0,
            power_uw: 10.0,
        };
        let approx = CircuitCost {
            power_uw: 5.0,
            ..exact
        };
        // whole network: exactly the circuit ratio
        assert!((pm.relative_power(&exact, &approx, None) - 50.0).abs() < 1e-9);
        // one layer: between 50 % and 100 %, closer to 100 %
        let one = pm.relative_power(&exact, &approx, Some(0));
        assert!(one > 90.0 && one < 100.0, "{one}");
        // exact in the layer: no change
        assert!((pm.relative_power(&exact, &exact, Some(3)) - 100.0).abs() < 1e-9);
        // fractions sum to 1
        let s: f64 = (0..pm.layer_mults.len()).map(|i| pm.layer_fraction(i)).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}
