//! # evoapproxlib
//!
//! Reproduction of *"Using Libraries of Approximate Circuits in Design of
//! Hardware Accelerators of Deep Neural Networks"* (Mrazek, Sekanina,
//! Vasicek — AICAS 2020).
//!
//! The crate implements the full stack the paper describes:
//!
//! * [`circuit`] — gate-level netlist substrate: representation, bit-parallel
//!   simulation, exact adder/multiplier generators, truncation and BAM
//!   baseline approximations, a 45 nm-style area/power/delay cost model
//!   (substituting for Synopsys Design Compiler — see `DESIGN.md`), and the
//!   static-analysis subsystem (`circuit::analysis`, DESIGN.md §12):
//!   simulation-free well-formedness verification at every ingest boundary
//!   and a sound error-bound engine whose provable `wce_bound`/`wce_floor`/
//!   `exact_proven` facts ride alongside every entry's sampled metrics and
//!   power the CGP fitness pre-screen.
//! * [`cgp`] — Cartesian Genetic Programming engine: chromosome encoding,
//!   mutation, (1+λ) evolutionary strategy, all six error metrics of the
//!   paper (eqs. 1–6), single-objective error-constrained search,
//!   multi-objective Pareto-archive search, an island-model multi-deme
//!   variant for wide operands, and the deterministic job-pool campaign
//!   engine that fans independent runs across worker threads
//!   (`DESIGN.md` §6).
//! * [`cli`] — dependency-free clap-style command/flag layer used by the
//!   `evoapprox` binary (unknown flags are rejected, not ignored).
//! * [`library`] — the approximate-circuit library itself: typed entries with
//!   full metric characterisation, JSON persistence, Pareto-front extraction
//!   and the paper's "10 circuits evenly spaced along the power axis per
//!   metric" selection procedure (§III/§IV) — plus the compiled zero-copy
//!   binary store (`library compile`, DESIGN.md §10; format v2 carries the
//!   static bounds byte-exactly) and the `LibrarySource` Json|Compiled
//!   abstraction every read-only consumer loads through.
//! * [`accel`] — the DNN hardware-accelerator model: ResNet-N architecture
//!   descriptions, per-layer multiplier counts and the power model used to
//!   report "relative power of multipliers in convolutional layers".
//! * [`resilience`] — the resilience-analysis framework of §IV: LUT
//!   construction from netlists, per-layer and whole-network replacement
//!   campaigns fanned over the job pool, accuracy/power trade-off reports
//!   (Fig. 4, Table II) byte-identical for any worker count, and the
//!   shared evaluation cache that memoises `(network, multiplier, layer
//!   scope)` accuracies across campaigns, `/v1/select` and DSE.
//! * [`dse`] — design-space exploration (DESIGN.md §8): heterogeneous
//!   per-layer multiplier assignment in the autoAx mould — a probe
//!   campaign fits an additive least-squares QoR predictor and an
//!   analytic power model, greedy + seeded local search explores the
//!   assignment space over an accuracy-budget ladder on the predicted
//!   objectives, and only the predicted Pareto front (plus every uniform
//!   configuration, for the paper's whole-network baseline) is verified
//!   on the real backend. Deterministic for any `--jobs` value and
//!   byte-identical over HTTP vs in-process.
//! * [`runtime`] — inference runtimes behind one `EngineBackend` trait:
//!   the PJRT engine for the AOT-compiled HLO artifacts produced by
//!   `python/compile/aot.py`, and the pure-Rust `native` LUT-inference
//!   engine (quantized-weights artifact or seeded synthetic fallback)
//!   that needs no PJRT, no artifacts and no Python.
//! * [`coordinator`] — the L3 coordinator: backend selection
//!   (`auto`/`native`/`pjrt`), job scheduling of evolution and analysis
//!   campaigns, a dynamic batcher in front of the engines, and service
//!   metrics (with a Prometheus-style histogram renderer).
//! * [`server`] — the L4 service layer: a std-only evented HTTP/1.1
//!   server (`evoapprox serve`) built on a `poll(2)` readiness loop with
//!   keep-alive, pipelining, slowloris/idle deadlines and explicit 429
//!   backpressure, exposing classification through the batcher (deferred
//!   completions — no blocked threads), library census/Pareto/selection
//!   queries, bounded async campaign jobs and a Prometheus `/metrics`
//!   exporter; the `evoapprox fleet` shard/replica router scales the
//!   same surface across supervised `serve` processes, and the in-crate
//!   keep-alive HTTP client drives both from tests and the open-loop
//!   `loadgen` bench (DESIGN.md §7, §11).
//! * [`data`] — synthetic CIFAR-like dataset generation (shared, seeded
//!   generator mirrored by `python/compile/data.py`).
//! * [`obs`] — observability (DESIGN.md §13): per-thread span tracing
//!   into a bounded ring buffer exported as Chrome trace-event JSON
//!   (`GET /debug/trace`, `evoapprox trace dump`), a leveled JSON-lines
//!   logger (`--log-level`/`EVOAPPROX_LOG`) replacing ad-hoc stderr
//!   diagnostics, `X-Request-Id` correlation across router → shard →
//!   job-worker hops, and live per-stage job progress (stage/completed/
//!   total/ETA on `GET /v1/jobs/{id}`) — all off the data path, so the
//!   byte-identity contracts hold with collection enabled.
//!
//! Python (JAX + Pallas) is used only at build time: `make artifacts` trains
//! the ResNet family on the synthetic dataset and lowers the quantised
//! LUT-multiplier inference graphs to HLO text; the Rust binary is fully
//! self-contained afterwards.

pub mod accel;
pub mod cgp;
pub mod circuit;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dse;
pub mod library;
pub mod obs;
pub mod resilience;
pub mod runtime;
pub mod server;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
