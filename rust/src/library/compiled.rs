//! Compiled binary library store (DESIGN.md §10): a JSON library lowered
//! into a versioned, checksummed, little-endian flat file that a process
//! can open and query without deserialising a single untouched entry.
//!
//! Layout (all integers little-endian, all `f64`s exact IEEE-754 bit
//! patterns, no alignment requirements — every field is decoded with
//! `from_le_bytes` on byte offsets):
//!
//! ```text
//! header (160 bytes)
//!   0   magic            b"EVOAPXL1"
//!   8   format version   u32 (= 2)
//!   12  endianness tag   u32 (= 0x0A0B0C0D as LE bytes 0D 0C 0B 0A)
//!   16  n_entries        u64
//!   24  payload length   u64 (file length − header length)
//!   32  payload checksum u64 (FNV-1a over every payload byte)
//!   40  n_sections       u32 (= 7)
//!   44  record size      u32 (= 200)
//!   48  section table    7 × (offset u64, length u64), payload-relative
//! payload
//!   RECORDS   n_entries fixed 200-byte records (field table in `record`)
//!   STRINGS   interned UTF-8 blob (entry ids, origin strings)
//!   NETS      netlist blob: 9-byte nodes (kind u8, a u32, b u32) and
//!             4-byte output signal ids, per-record ranges
//!   CENSUS    64-byte rows: kind u8 + pad, width u32, count u64,
//!             area min/max f64, delay min/max f64, exact_proven u64,
//!             wce_bound_max f64 — precomputed `Library::census_rows`
//!             output in its (kind, width) order
//!   FNTAB     120-byte rows, one per distinct function, sorted by
//!             (kind, width): the entry list, 7 metric-sorted index lists
//!             (power + ER/MAE/MSE/MRE/WCE/WCRE) and 6 precomputed
//!             (power, metric) Pareto fronts, all as (offset, count)
//!             pairs into IDX
//!   IDX       u32 entry-index arena backing the FNTAB lists
//!   IDSORT    n_entries u32 entry indices sorted by id (binary `get`)
//! ```
//!
//! Versioning rules: the magic pins the family, `format version` is bumped
//! on any incompatible layout change and the reader rejects versions it
//! does not know. Version 2 appended the static-analysis bound fields
//! (`circuit::analysis`) to records and census rows; v1 files are rejected
//! (recompile from the JSON source). The endianness tag guards against a big-endian writer —
//! the format is defined little-endian and a reader on any host decodes
//! it with explicit `from_le_bytes`, so the tag only rejects files from a
//! hypothetical non-conforming producer. The record-size field lets a
//! reader reject records it would mis-stride.
//!
//! The reader ([`CompiledLibrary`]) slurps the file into one read-only
//! slab (`std::fs::read` — the std-only substitution for `mmap(2)`, per
//! DESIGN.md's no-external-crates policy), validates header, checksum and
//! every cross-section reference once, and then serves entries as
//! [`EntryView`]s — zero-copy windows that materialise an owned
//! [`Entry`] only on demand. Census, Pareto and sorted-by-metric queries
//! never touch entry records at all: they are answered straight from the
//! precomputed CENSUS/FNTAB/IDX sections.

use std::collections::HashMap;
use std::path::Path;

use crate::cgp::metrics::{ErrorMetrics, Metric};
use crate::circuit::analysis::StaticBounds;
use crate::circuit::cost::CircuitCost;
use crate::circuit::gate::GateKind;
use crate::circuit::netlist::{Netlist, Node};
use crate::circuit::verify::ArithFn;

use super::entry::{Entry, Origin};
use super::selection::pareto_indices;
use super::store::{CensusRow, Library};

/// File magic — first 8 bytes of every compiled library.
pub const MAGIC: [u8; 8] = *b"EVOAPXL1";
/// Current format version (2: records and census rows carry the
/// `circuit::analysis` static bound fields).
pub const FORMAT_VERSION: u32 = 2;
/// Byte-order sentinel: decodes to this value only through `from_le_bytes`.
const ENDIAN_TAG: u32 = 0x0A0B_0C0D;
const N_SECTIONS: usize = 7;
/// Fixed header length; the payload starts here.
pub const HEADER_LEN: usize = 48 + N_SECTIONS * 16;
const RECORD_SIZE: usize = 200;
const CENSUS_ROW_SIZE: usize = 64;
const FNTAB_ROW_SIZE: usize = 120;
const NODE_SIZE: usize = 9;

// Section indices into the header table.
const SEC_RECORDS: usize = 0;
const SEC_STRINGS: usize = 1;
const SEC_NETS: usize = 2;
const SEC_CENSUS: usize = 3;
const SEC_FNTAB: usize = 4;
const SEC_IDX: usize = 5;
const SEC_IDSORT: usize = 6;

// Record field offsets (see the module doc). Kept as named constants so
// writer and reader cannot drift.
const R_ID_OFF: usize = 0; // u32 into STRINGS
const R_ID_LEN: usize = 4; // u32
const R_KIND: usize = 8; // u8: 0 = add, 1 = mul
const R_EXHAUSTIVE: usize = 9; // u8 bool
const R_WIDTH: usize = 10; // u16
const R_N_INPUTS: usize = 12; // u32
const R_NODES_OFF: usize = 16; // u64 into NETS
const R_N_NODES: usize = 24; // u32
const R_N_OUTPUTS: usize = 28; // u32
const R_OUTS_OFF: usize = 32; // u64 into NETS
const R_METRICS: usize = 40; // 6 × f64: er, mae, mse, mre, wce, wcre
const R_N_VECTORS: usize = 88; // u64
const R_GATES: usize = 96; // u64
const R_COST: usize = 104; // 5 × f64: area, delay, leakage, dynamic, power
const R_ORIGIN_TAG: usize = 144; // u8 (+3 pad): 0 seed, 1 evolved, 2 trunc, 3 bam
const R_ORIGIN_STR_OFF: usize = 148; // u32 into STRINGS
const R_ORIGIN_STR_LEN: usize = 152; // u32
const R_ORIGIN_X: usize = 156; // u64: e_max_permille / keep / h
const R_ORIGIN_Y: usize = 164; // u64: seed / v
const R_WCE_BOUND: usize = 172; // f64: provable WCE upper bound
const R_MAE_BOUND: usize = 180; // f64: provable MAE upper bound
const R_WCE_FLOOR: usize = 188; // f64: provable WCE lower bound
const R_EXACT_PROVEN: usize = 196; // u8 bool (+3 pad)

/// Canonical metric order of the FNTAB index/front lists.
pub const METRIC_ORDER: [Metric; 6] = [
    Metric::Er,
    Metric::Mae,
    Metric::Mse,
    Metric::Mre,
    Metric::Wce,
    Metric::Wcre,
];

/// Position of a metric in [`METRIC_ORDER`] (FNTAB slot number).
pub fn metric_slot(m: Metric) -> usize {
    match m {
        Metric::Er => 0,
        Metric::Mae => 1,
        Metric::Mse => 2,
        Metric::Mre => 3,
        Metric::Wce => 4,
        Metric::Wcre => 5,
    }
}

/// Incremental FNV-1a over bytes (the checksum of the payload, and the
/// fingerprint of JSON-backed sources).
pub(crate) struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

impl Fnv64 {
    pub(crate) fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub(crate) fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x1000_0000_01b3);
        }
    }

    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// String arena with interning — repeated origin strings (metric names,
/// seed labels) are stored once.
struct StrArena {
    bytes: Vec<u8>,
    memo: HashMap<String, (u32, u32)>,
}

impl StrArena {
    fn new() -> StrArena {
        StrArena {
            bytes: Vec::new(),
            memo: HashMap::new(),
        }
    }

    fn intern(&mut self, s: &str) -> (u32, u32) {
        if let Some(&r) = self.memo.get(s) {
            return r;
        }
        let r = (self.bytes.len() as u32, s.len() as u32);
        self.bytes.extend_from_slice(s.as_bytes());
        self.memo.insert(s.to_string(), r);
        r
    }
}

fn fn_kind_code(f: ArithFn) -> u8 {
    match f {
        ArithFn::Add { .. } => 0,
        ArithFn::Mul { .. } => 1,
    }
}

fn origin_fields(o: &Origin) -> (u8, &str, u64, u64) {
    match o {
        Origin::Seed(s) => (0, s.as_str(), 0, 0),
        Origin::Evolved {
            metric,
            e_max_permille,
            seed,
        } => (1, metric.as_str(), *e_max_permille, *seed),
        Origin::Truncated { keep } => (2, "", *keep as u64, 0),
        Origin::Bam { h, v } => (3, "", *h as u64, *v as u64),
    }
}

/// Append an index list to IDX; returns its `(offset, count)` pair in
/// u32 elements.
fn push_idx(idx: &mut Vec<u8>, list: &[u32]) -> (u32, u32) {
    let off = (idx.len() / 4) as u32;
    for &v in list {
        idx.extend_from_slice(&v.to_le_bytes());
    }
    (off, list.len() as u32)
}

fn push_pair(out: &mut Vec<u8>, (off, len): (u32, u32)) {
    out.extend_from_slice(&off.to_le_bytes());
    out.extend_from_slice(&len.to_le_bytes());
}

/// Lower an in-memory [`Library`] into the compiled byte format.
///
/// The precomputed CENSUS rows and FNTAB fronts are produced by the very
/// same `census_rows`/`pareto_indices` functions the JSON query path runs
/// per request, so a compiled store answers those queries byte-identically
/// by construction.
pub fn compile_library(lib: &Library) -> Vec<u8> {
    let entries = lib.entries();
    let mut strings = StrArena::new();
    let mut nets: Vec<u8> = Vec::new();
    let mut records: Vec<u8> = Vec::with_capacity(entries.len() * RECORD_SIZE);

    for e in entries {
        let (id_off, id_len) = strings.intern(&e.id);
        let nodes_off = nets.len() as u64;
        for n in &e.netlist.nodes {
            nets.push(n.kind.code());
            nets.extend_from_slice(&n.a.to_le_bytes());
            nets.extend_from_slice(&n.b.to_le_bytes());
        }
        let outs_off = nets.len() as u64;
        for &o in &e.netlist.outputs {
            nets.extend_from_slice(&o.to_le_bytes());
        }
        let (otag, ostr, ox, oy) = origin_fields(&e.origin);
        let (ostr_off, ostr_len) = strings.intern(ostr);

        let r0 = records.len();
        records.extend_from_slice(&id_off.to_le_bytes());
        records.extend_from_slice(&id_len.to_le_bytes());
        records.push(fn_kind_code(e.f));
        records.push(e.metrics.exhaustive as u8);
        records.extend_from_slice(&(e.f.width() as u16).to_le_bytes());
        records.extend_from_slice(&e.netlist.n_inputs.to_le_bytes());
        records.extend_from_slice(&nodes_off.to_le_bytes());
        records.extend_from_slice(&(e.netlist.nodes.len() as u32).to_le_bytes());
        records.extend_from_slice(&(e.netlist.outputs.len() as u32).to_le_bytes());
        records.extend_from_slice(&outs_off.to_le_bytes());
        for v in [
            e.metrics.er,
            e.metrics.mae,
            e.metrics.mse,
            e.metrics.mre,
            e.metrics.wce,
            e.metrics.wcre,
        ] {
            records.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        records.extend_from_slice(&e.metrics.n_vectors.to_le_bytes());
        records.extend_from_slice(&(e.cost.gates as u64).to_le_bytes());
        for v in [
            e.cost.area_um2,
            e.cost.delay_ps,
            e.cost.leakage_uw,
            e.cost.dynamic_uw,
            e.cost.power_uw,
        ] {
            records.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        records.push(otag);
        records.extend_from_slice(&[0u8; 3]);
        records.extend_from_slice(&ostr_off.to_le_bytes());
        records.extend_from_slice(&ostr_len.to_le_bytes());
        records.extend_from_slice(&ox.to_le_bytes());
        records.extend_from_slice(&oy.to_le_bytes());
        for v in [e.bounds.wce_bound, e.bounds.mae_bound, e.bounds.wce_floor] {
            records.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        records.push(e.bounds.exact_proven as u8);
        records.extend_from_slice(&[0u8; 3]);
        debug_assert_eq!(records.len() - r0, RECORD_SIZE);
    }

    // CENSUS: the precomputed census_rows, in their canonical order.
    let mut census: Vec<u8> = Vec::new();
    for r in lib.census_rows() {
        census.push(if r.kind == "adder" { 0 } else { 1 });
        census.extend_from_slice(&[0u8; 3]);
        census.extend_from_slice(&r.width.to_le_bytes());
        census.extend_from_slice(&(r.count as u64).to_le_bytes());
        for v in [
            r.area_um2_min,
            r.area_um2_max,
            r.delay_ps_min,
            r.delay_ps_max,
        ] {
            census.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        census.extend_from_slice(&r.exact_proven.to_le_bytes());
        census.extend_from_slice(&r.wce_bound_max.to_bits().to_le_bytes());
    }

    // Group entries per function, in insertion order (the order every
    // JSON-path query iterates), with groups sorted by (kind, width).
    let mut groups: Vec<(ArithFn, Vec<u32>)> = Vec::new();
    let mut group_of: HashMap<ArithFn, usize> = HashMap::new();
    for (i, e) in entries.iter().enumerate() {
        let g = *group_of.entry(e.f).or_insert_with(|| {
            groups.push((e.f, Vec::new()));
            groups.len() - 1
        });
        groups[g].1.push(i as u32);
    }
    groups.sort_by_key(|(f, _)| (fn_kind_code(*f), f.width()));

    let mut fntab: Vec<u8> = Vec::new();
    let mut idx: Vec<u8> = Vec::new();
    for (f, members) in &groups {
        let refs: Vec<&Entry> = members.iter().map(|&i| &entries[i as usize]).collect();
        fntab.extend_from_slice(&(fn_kind_code(*f) as u32).to_le_bytes());
        fntab.extend_from_slice(&f.width().to_le_bytes());
        push_pair(&mut fntab, push_idx(&mut idx, members));
        // 7 sorted index lists: power first, then the six metrics — each
        // ordered by (value, insertion position) so ties stay stable.
        let keyed_sort = |key: &dyn Fn(&Entry) -> f64| -> Vec<u32> {
            let mut order: Vec<u32> = members.clone();
            order.sort_by(|&a, &b| {
                key(&entries[a as usize])
                    .total_cmp(&key(&entries[b as usize]))
                    .then(a.cmp(&b))
            });
            order
        };
        let by_power = keyed_sort(&|e: &Entry| e.cost.power_uw);
        push_pair(&mut fntab, push_idx(&mut idx, &by_power));
        for m in METRIC_ORDER {
            let sorted = keyed_sort(&move |e: &Entry| m.of(&e.metrics));
            push_pair(&mut fntab, push_idx(&mut idx, &sorted));
        }
        // 6 precomputed (power, metric) Pareto fronts, in insertion order
        // (exactly what `pareto_indices` over the JSON path yields).
        for m in METRIC_ORDER {
            let front: Vec<u32> = pareto_indices(&refs, m)
                .into_iter()
                .map(|p| members[p])
                .collect();
            push_pair(&mut fntab, push_idx(&mut idx, &front));
        }
    }

    // IDSORT: entry indices ordered by id bytes (ties by index) for
    // binary-search `get`.
    let mut idsort: Vec<u32> = (0..entries.len() as u32).collect();
    idsort.sort_by(|&a, &b| {
        entries[a as usize]
            .id
            .as_bytes()
            .cmp(entries[b as usize].id.as_bytes())
            .then(a.cmp(&b))
    });
    let idsort_bytes: Vec<u8> = idsort.iter().flat_map(|v| v.to_le_bytes()).collect();

    // Assemble the payload and prepend the header.
    let sections: [&[u8]; N_SECTIONS] = [
        &records,
        &strings.bytes,
        &nets,
        &census,
        &fntab,
        &idx,
        &idsort_bytes,
    ];
    let payload_len: usize = sections.iter().map(|s| s.len()).sum();
    let mut out = Vec::with_capacity(HEADER_LEN + payload_len);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&ENDIAN_TAG.to_le_bytes());
    out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    out.extend_from_slice(&(payload_len as u64).to_le_bytes());
    let checksum_at = out.len();
    out.extend_from_slice(&0u64.to_le_bytes()); // checksum patched below
    out.extend_from_slice(&(N_SECTIONS as u32).to_le_bytes());
    out.extend_from_slice(&(RECORD_SIZE as u32).to_le_bytes());
    let mut off = 0u64;
    for s in sections {
        out.extend_from_slice(&off.to_le_bytes());
        out.extend_from_slice(&(s.len() as u64).to_le_bytes());
        off += s.len() as u64;
    }
    debug_assert_eq!(out.len(), HEADER_LEN);
    for s in sections {
        out.extend_from_slice(s);
    }
    let checksum = fnv1a_bytes(&out[HEADER_LEN..]);
    out[checksum_at..checksum_at + 8].copy_from_slice(&checksum.to_le_bytes());
    out
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

fn rd_u16(b: &[u8], off: usize) -> u16 {
    u16::from_le_bytes(b[off..off + 2].try_into().unwrap())
}

fn rd_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

fn rd_u64(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

fn rd_f64(b: &[u8], off: usize) -> f64 {
    f64::from_bits(rd_u64(b, off))
}

/// One decoded FNTAB row: the per-function index bundle.
#[derive(Debug, Clone, Copy)]
struct FnGroup {
    f: ArithFn,
    entries: (u32, u32),
    sorted: [(u32, u32); 7],
    fronts: [(u32, u32); 6],
}

/// Zero-copy reader over a compiled library slab.
///
/// Construction validates the header, the payload checksum, every section
/// bound and every cross-section reference (string ranges, netlist ranges,
/// index values, gate codes), so the query accessors and
/// [`EntryView::materialise`] are infallible afterwards.
pub struct CompiledLibrary {
    data: Box<[u8]>,
    n_entries: usize,
    /// Absolute `(start, len)` of each section within `data`.
    sections: [(usize, usize); N_SECTIONS],
    fns: Vec<FnGroup>,
    checksum: u64,
}

impl std::fmt::Debug for CompiledLibrary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledLibrary")
            .field("n_entries", &self.n_entries)
            .field("bytes", &self.data.len())
            .field("fns", &self.fns.len())
            .finish()
    }
}

impl CompiledLibrary {
    /// Slab-load and validate a compiled library file.
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<CompiledLibrary> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        CompiledLibrary::from_bytes(bytes)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Validate and adopt an in-memory slab.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<CompiledLibrary, String> {
        let data = bytes.into_boxed_slice();
        if data.len() < HEADER_LEN {
            return Err(format!(
                "not a compiled library: {} bytes is shorter than the {HEADER_LEN}-byte header",
                data.len()
            ));
        }
        if data[..8] != MAGIC {
            return Err("bad magic: not a compiled library file".to_string());
        }
        let version = rd_u32(&data, 8);
        if version != FORMAT_VERSION {
            return Err(format!(
                "unsupported format version {version} (reader knows {FORMAT_VERSION})"
            ));
        }
        if rd_u32(&data, 12) != ENDIAN_TAG {
            return Err("endianness tag mismatch: file not written little-endian".to_string());
        }
        let n_entries = rd_u64(&data, 16) as usize;
        let payload_len = rd_u64(&data, 24) as usize;
        if payload_len != data.len() - HEADER_LEN {
            return Err(format!(
                "truncated or padded file: header declares a {payload_len}-byte payload, \
                 found {}",
                data.len() - HEADER_LEN
            ));
        }
        let checksum = rd_u64(&data, 32);
        if rd_u32(&data, 40) as usize != N_SECTIONS {
            return Err("unexpected section count".to_string());
        }
        if rd_u32(&data, 44) as usize != RECORD_SIZE {
            return Err("unexpected record size".to_string());
        }
        let actual = fnv1a_bytes(&data[HEADER_LEN..]);
        if actual != checksum {
            return Err(format!(
                "payload checksum mismatch (file corrupt): stored {checksum:#018x}, \
                 computed {actual:#018x}"
            ));
        }
        let mut sections = [(0usize, 0usize); N_SECTIONS];
        for (s, slot) in sections.iter_mut().enumerate() {
            let off = rd_u64(&data, 48 + s * 16) as usize;
            let len = rd_u64(&data, 48 + s * 16 + 8) as usize;
            let end = off
                .checked_add(len)
                .ok_or_else(|| format!("section {s}: offset overflow"))?;
            if end > payload_len {
                return Err(format!(
                    "section {s} [{off}, {end}) exceeds the {payload_len}-byte payload"
                ));
            }
            *slot = (HEADER_LEN + off, len);
        }
        let lib = CompiledLibrary {
            data,
            n_entries,
            sections,
            fns: Vec::new(),
            checksum,
        };
        lib.validate()
    }

    fn section(&self, s: usize) -> &[u8] {
        let (start, len) = self.sections[s];
        &self.data[start..start + len]
    }

    /// Structural validation: decode FNTAB, then bounds-check every
    /// reference so views never have to.
    fn validate(mut self) -> Result<CompiledLibrary, String> {
        let n = self.n_entries;
        if self.section(SEC_RECORDS).len() != n * RECORD_SIZE {
            return Err(format!(
                "RECORDS section is {} bytes, expected {} for {n} entries",
                self.section(SEC_RECORDS).len(),
                n * RECORD_SIZE
            ));
        }
        if self.section(SEC_CENSUS).len() % CENSUS_ROW_SIZE != 0 {
            return Err("CENSUS section is not a whole number of rows".to_string());
        }
        if self.section(SEC_FNTAB).len() % FNTAB_ROW_SIZE != 0 {
            return Err("FNTAB section is not a whole number of rows".to_string());
        }
        if self.section(SEC_IDX).len() % 4 != 0 {
            return Err("IDX section is not a whole number of u32s".to_string());
        }
        if self.section(SEC_IDSORT).len() != n * 4 {
            return Err("IDSORT section length does not match the entry count".to_string());
        }
        let idx_count = (self.section(SEC_IDX).len() / 4) as u32;
        // every IDX and IDSORT element must name a real entry
        for s in [SEC_IDX, SEC_IDSORT] {
            let b = self.section(s);
            for c in b.chunks_exact(4) {
                let v = u32::from_le_bytes(c.try_into().unwrap());
                if v as usize >= n {
                    return Err(format!("index {v} out of range (n_entries = {n})"));
                }
            }
        }
        // decode + validate FNTAB
        let fntab = self.section(SEC_FNTAB);
        let mut fns = Vec::with_capacity(fntab.len() / FNTAB_ROW_SIZE);
        for row in fntab.chunks_exact(FNTAB_ROW_SIZE) {
            let kind = rd_u32(row, 0);
            let width = rd_u32(row, 4);
            let f = match kind {
                0 => ArithFn::Add { w: width },
                1 => ArithFn::Mul { w: width },
                k => return Err(format!("FNTAB: unknown function kind {k}")),
            }
            .validated()?;
            let pair = |at: usize| -> Result<(u32, u32), String> {
                let off = rd_u32(row, at);
                let len = rd_u32(row, at + 4);
                if off.checked_add(len).map_or(true, |end| end > idx_count) {
                    return Err(format!(
                        "FNTAB {}: index list [{off}, +{len}) exceeds IDX ({idx_count} u32s)",
                        f.tag()
                    ));
                }
                Ok((off, len))
            };
            let entries = pair(8)?;
            let mut sorted = [(0u32, 0u32); 7];
            for (s, slot) in sorted.iter_mut().enumerate() {
                *slot = pair(16 + s * 8)?;
            }
            let mut fronts = [(0u32, 0u32); 6];
            for (s, slot) in fronts.iter_mut().enumerate() {
                *slot = pair(72 + s * 8)?;
            }
            fns.push(FnGroup {
                f,
                entries,
                sorted,
                fronts,
            });
        }
        self.fns = fns;
        // per-record references
        let strings_len = self.section(SEC_STRINGS).len();
        let nets = self.section(SEC_NETS);
        for i in 0..n {
            let r = &self.section(SEC_RECORDS)[i * RECORD_SIZE..(i + 1) * RECORD_SIZE];
            let err = |what: &str| format!("record {i}: {what}");
            let str_range = |off: usize, len_at: usize, what: &str| -> Result<(), String> {
                let (o, l) = (rd_u32(r, off) as usize, rd_u32(r, len_at) as usize);
                let end = o.checked_add(l).ok_or_else(|| err(what))?;
                if end > strings_len {
                    return Err(err(&format!(
                        "{what} [{o}, {end}) exceeds the {strings_len}-byte string arena"
                    )));
                }
                std::str::from_utf8(&self.section(SEC_STRINGS)[o..end])
                    .map_err(|_| err(&format!("{what} is not UTF-8")))?;
                Ok(())
            };
            str_range(R_ID_OFF, R_ID_LEN, "id")?;
            str_range(R_ORIGIN_STR_OFF, R_ORIGIN_STR_LEN, "origin string")?;
            let kind = r[R_KIND];
            if kind > 1 {
                return Err(err(&format!("unknown function kind {kind}")));
            }
            let w = rd_u16(r, R_WIDTH) as u32;
            match kind {
                0 => ArithFn::Add { w },
                _ => ArithFn::Mul { w },
            }
            .validated()
            .map_err(|e| err(&e))?;
            if r[R_ORIGIN_TAG] > 3 {
                return Err(err(&format!("unknown origin tag {}", r[R_ORIGIN_TAG])));
            }
            let nodes_off = rd_u64(r, R_NODES_OFF) as usize;
            let n_nodes = rd_u32(r, R_N_NODES) as usize;
            let nodes_end = nodes_off
                .checked_add(n_nodes.checked_mul(NODE_SIZE).ok_or_else(|| err("nodes"))?)
                .ok_or_else(|| err("nodes"))?;
            let outs_off = rd_u64(r, R_OUTS_OFF) as usize;
            let n_outputs = rd_u32(r, R_N_OUTPUTS) as usize;
            let outs_end = outs_off
                .checked_add(n_outputs.checked_mul(4).ok_or_else(|| err("outputs"))?)
                .ok_or_else(|| err("outputs"))?;
            if nodes_end > nets.len() || outs_end > nets.len() {
                return Err(err("netlist range exceeds the NETS arena"));
            }
            for c in nets[nodes_off..nodes_end].chunks_exact(NODE_SIZE) {
                if GateKind::from_code(c[0]).is_none() {
                    return Err(err(&format!("invalid gate code {}", c[0])));
                }
            }
        }
        Ok(self)
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.n_entries
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.n_entries == 0
    }

    /// Payload checksum — doubles as the library fingerprint.
    pub fn fingerprint(&self) -> u64 {
        self.checksum
    }

    /// Precomputed census rows, straight from the CENSUS section — no
    /// entry record is touched.
    pub fn census_rows(&self) -> Vec<CensusRow> {
        self.section(SEC_CENSUS)
            .chunks_exact(CENSUS_ROW_SIZE)
            .map(|row| CensusRow {
                kind: if row[0] == 0 { "adder" } else { "multiplier" }.to_string(),
                width: rd_u32(row, 4),
                count: rd_u64(row, 8) as usize,
                area_um2_min: rd_f64(row, 16),
                area_um2_max: rd_f64(row, 24),
                delay_ps_min: rd_f64(row, 32),
                delay_ps_max: rd_f64(row, 40),
                exact_proven: rd_u64(row, 48),
                wce_bound_max: rd_f64(row, 56),
            })
            .collect()
    }

    fn group(&self, f: ArithFn) -> Option<&FnGroup> {
        self.fns.iter().find(|g| g.f == f)
    }

    fn idx_list(&self, (off, len): (u32, u32)) -> Vec<usize> {
        let b = self.section(SEC_IDX);
        (off..off + len)
            .map(|i| rd_u32(b, i as usize * 4) as usize)
            .collect()
    }

    /// Indices of the entries implementing `f`, in insertion order.
    pub fn for_fn_indices(&self, f: ArithFn) -> Vec<usize> {
        self.group(f)
            .map(|g| self.idx_list(g.entries))
            .unwrap_or_default()
    }

    /// Number of entries implementing `f` (no index materialisation).
    pub fn for_fn_len(&self, f: ArithFn) -> usize {
        self.group(f).map_or(0, |g| g.entries.1 as usize)
    }

    /// Precomputed (power, `metric`) Pareto-front indices for `f`, in
    /// insertion order — the FNTAB answer, no dominance scan.
    pub fn front_indices(&self, f: ArithFn, metric: Metric) -> Vec<usize> {
        self.group(f)
            .map(|g| self.idx_list(g.fronts[metric_slot(metric)]))
            .unwrap_or_default()
    }

    /// Indices of the entries implementing `f` sorted ascending by
    /// `metric` (ties by insertion order).
    pub fn sorted_indices(&self, f: ArithFn, metric: Metric) -> Vec<usize> {
        self.group(f)
            .map(|g| self.idx_list(g.sorted[1 + metric_slot(metric)]))
            .unwrap_or_default()
    }

    /// Indices of the entries implementing `f` sorted ascending by power.
    pub fn sorted_by_power(&self, f: ArithFn) -> Vec<usize> {
        self.group(f)
            .map(|g| self.idx_list(g.sorted[0]))
            .unwrap_or_default()
    }

    /// The functions the library holds entries for, in (kind, width) order.
    pub fn functions(&self) -> Vec<ArithFn> {
        self.fns.iter().map(|g| g.f).collect()
    }

    /// Lazily-materialised view of entry `i`. Panics if out of range.
    pub fn entry(&self, i: usize) -> EntryView<'_> {
        assert!(i < self.n_entries, "entry index {i} out of range");
        EntryView { lib: self, i }
    }

    /// Binary-search an entry by id over the IDSORT section.
    pub fn get(&self, id: &str) -> Option<EntryView<'_>> {
        let b = self.section(SEC_IDSORT);
        let (mut lo, mut hi) = (0usize, self.n_entries);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let e = self.entry(rd_u32(b, mid * 4) as usize);
            match e.id().as_bytes().cmp(id.as_bytes()) {
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
                std::cmp::Ordering::Equal => return Some(e),
            }
        }
        None
    }
}

/// A zero-copy view of one compiled entry: scalar accessors decode only
/// the bytes they need; [`EntryView::materialise`] builds the owned
/// [`Entry`] (decoding the netlist) on demand.
#[derive(Clone, Copy)]
pub struct EntryView<'a> {
    lib: &'a CompiledLibrary,
    i: usize,
}

impl<'a> EntryView<'a> {
    fn rec(&self) -> &'a [u8] {
        &self.lib.section(SEC_RECORDS)[self.i * RECORD_SIZE..(self.i + 1) * RECORD_SIZE]
    }

    fn str_at(&self, off_at: usize, len_at: usize) -> &'a str {
        let r = self.rec();
        let (o, l) = (rd_u32(r, off_at) as usize, rd_u32(r, len_at) as usize);
        // validated at load time
        std::str::from_utf8(&self.lib.section(SEC_STRINGS)[o..o + l]).unwrap()
    }

    /// Index of this entry in the record table.
    pub fn index(&self) -> usize {
        self.i
    }

    /// Entry id (borrowed from the string arena).
    pub fn id(&self) -> &'a str {
        self.str_at(R_ID_OFF, R_ID_LEN)
    }

    /// Arithmetic function.
    pub fn f(&self) -> ArithFn {
        let r = self.rec();
        let w = rd_u16(r, R_WIDTH) as u32;
        match r[R_KIND] {
            0 => ArithFn::Add { w },
            _ => ArithFn::Mul { w },
        }
    }

    /// Total power [µW] — the selection/front ranking key.
    pub fn power_uw(&self) -> f64 {
        rd_f64(self.rec(), R_COST + 32)
    }

    /// One error metric, without decoding the rest.
    pub fn metric(&self, m: Metric) -> f64 {
        rd_f64(self.rec(), R_METRICS + metric_slot(m) * 8)
    }

    /// All six error metrics.
    pub fn metrics(&self) -> ErrorMetrics {
        let r = self.rec();
        ErrorMetrics {
            er: rd_f64(r, R_METRICS),
            mae: rd_f64(r, R_METRICS + 8),
            mse: rd_f64(r, R_METRICS + 16),
            mre: rd_f64(r, R_METRICS + 24),
            wce: rd_f64(r, R_METRICS + 32),
            wcre: rd_f64(r, R_METRICS + 40),
            n_vectors: rd_u64(r, R_N_VECTORS),
            exhaustive: r[R_EXHAUSTIVE] != 0,
        }
    }

    /// Synthesis-model cost.
    pub fn cost(&self) -> CircuitCost {
        let r = self.rec();
        CircuitCost {
            gates: rd_u64(r, R_GATES) as usize,
            area_um2: rd_f64(r, R_COST),
            delay_ps: rd_f64(r, R_COST + 8),
            leakage_uw: rd_f64(r, R_COST + 16),
            dynamic_uw: rd_f64(r, R_COST + 24),
            power_uw: rd_f64(r, R_COST + 32),
        }
    }

    /// Provable static error bounds (`circuit::analysis`).
    pub fn bounds(&self) -> StaticBounds {
        let r = self.rec();
        StaticBounds {
            wce_bound: rd_f64(r, R_WCE_BOUND),
            mae_bound: rd_f64(r, R_MAE_BOUND),
            wce_floor: rd_f64(r, R_WCE_FLOOR),
            exact_proven: r[R_EXACT_PROVEN] != 0,
        }
    }

    /// Provenance.
    pub fn origin(&self) -> Origin {
        let r = self.rec();
        let s = self.str_at(R_ORIGIN_STR_OFF, R_ORIGIN_STR_LEN);
        let x = rd_u64(r, R_ORIGIN_X);
        let y = rd_u64(r, R_ORIGIN_Y);
        match r[R_ORIGIN_TAG] {
            0 => Origin::Seed(s.to_string()),
            1 => Origin::Evolved {
                metric: s.to_string(),
                e_max_permille: x,
                seed: y,
            },
            2 => Origin::Truncated { keep: x as u32 },
            _ => Origin::Bam {
                h: x as u32,
                v: y as u32,
            },
        }
    }

    /// Decode the full owned [`Entry`] — netlist included, with the
    /// Table-II percentage view recomputed exactly as the JSON loader
    /// does, so a materialised view is byte-identical to its
    /// `Entry::from_json` twin.
    pub fn materialise(&self) -> Entry {
        let r = self.rec();
        let id = self.id().to_string();
        let f = self.f();
        let mut netlist = Netlist::new(rd_u32(r, R_N_INPUTS), id.clone());
        let nets = self.lib.section(SEC_NETS);
        let nodes_off = rd_u64(r, R_NODES_OFF) as usize;
        let n_nodes = rd_u32(r, R_N_NODES) as usize;
        for c in nets[nodes_off..nodes_off + n_nodes * NODE_SIZE].chunks_exact(NODE_SIZE) {
            netlist.nodes.push(Node {
                kind: GateKind::from_code(c[0]).unwrap(), // validated at load
                a: rd_u32(c, 1),
                b: rd_u32(c, 5),
            });
        }
        let outs_off = rd_u64(r, R_OUTS_OFF) as usize;
        let n_outputs = rd_u32(r, R_N_OUTPUTS) as usize;
        for c in nets[outs_off..outs_off + n_outputs * 4].chunks_exact(4) {
            netlist.outputs.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
        let metrics = self.metrics();
        Entry {
            id,
            f,
            rel: metrics.as_percentages(f),
            netlist,
            metrics,
            cost: self.cost(),
            bounds: self.bounds(),
            origin: self.origin(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::baselines::bam_multiplier;
    use crate::circuit::cost::CostModel;
    use crate::circuit::generators::{ripple_carry_adder, wallace_multiplier};

    fn small_library() -> Library {
        let model = CostModel::default();
        let mut lib = Library::new();
        let f = ArithFn::Mul { w: 8 };
        lib.insert(Entry::characterise(
            wallace_multiplier(8),
            f,
            &model,
            Origin::Seed("wallace".into()),
        ));
        for (h, v) in [(0, 2), (0, 4), (1, 3), (0, 6)] {
            lib.insert(Entry::characterise(
                bam_multiplier(8, h, v),
                f,
                &model,
                Origin::Bam { h, v },
            ));
        }
        lib.insert(Entry::characterise(
            ripple_carry_adder(8),
            ArithFn::Add { w: 8 },
            &model,
            Origin::Seed("rca8".into()),
        ));
        lib
    }

    #[test]
    fn record_layout_constants_are_consistent() {
        assert_eq!(R_WCE_BOUND, R_ORIGIN_Y + 8);
        assert_eq!(R_MAE_BOUND, R_WCE_BOUND + 8);
        assert_eq!(R_WCE_FLOOR, R_MAE_BOUND + 8);
        assert_eq!(R_EXACT_PROVEN, R_WCE_FLOOR + 8);
        assert_eq!(R_EXACT_PROVEN + 4, RECORD_SIZE);
        assert_eq!(R_METRICS, R_OUTS_OFF + 8);
        assert_eq!(R_N_VECTORS, R_METRICS + 48);
        assert_eq!(R_ORIGIN_TAG, R_COST + 40);
        assert_eq!(HEADER_LEN, 160);
    }

    #[test]
    fn compile_round_trips_every_field() {
        let lib = small_library();
        let c = CompiledLibrary::from_bytes(compile_library(&lib)).unwrap();
        assert_eq!(c.len(), lib.len());
        for (i, e) in lib.entries().iter().enumerate() {
            let v = c.entry(i);
            assert_eq!(v.id(), e.id);
            assert_eq!(v.f(), e.f);
            assert_eq!(v.origin(), e.origin);
            let m = v.materialise();
            assert_eq!(m.netlist, e.netlist);
            assert_eq!(m.metrics, e.metrics);
            assert_eq!(m.cost, e.cost);
            assert_eq!(m.rel, e.rel);
            // bound fields survive byte-exactly (IEEE-754 bit patterns)
            assert_eq!(m.bounds.wce_bound.to_bits(), e.bounds.wce_bound.to_bits());
            assert_eq!(m.bounds.mae_bound.to_bits(), e.bounds.mae_bound.to_bits());
            assert_eq!(m.bounds.wce_floor.to_bits(), e.bounds.wce_floor.to_bits());
            assert_eq!(m.bounds.exact_proven, e.bounds.exact_proven);
            assert_eq!(v.bounds(), e.bounds);
        }
    }

    #[test]
    fn census_and_fronts_match_the_json_path() {
        let lib = small_library();
        let c = CompiledLibrary::from_bytes(compile_library(&lib)).unwrap();
        assert_eq!(c.census_rows(), lib.census_rows());
        let f = ArithFn::Mul { w: 8 };
        let all = lib.for_fn(f);
        for m in METRIC_ORDER {
            let want: Vec<&str> = pareto_indices(&all, m)
                .into_iter()
                .map(|i| all[i].id.as_str())
                .collect();
            let got: Vec<&str> = c
                .front_indices(f, m)
                .into_iter()
                .map(|i| {
                    // leak-free borrow: compare through fresh views
                    c.entry(i).id()
                })
                .collect();
            assert_eq!(got, want, "{m:?}");
        }
        // sorted-by-power really is sorted
        let order = c.sorted_by_power(f);
        assert_eq!(order.len(), all.len());
        for w in order.windows(2) {
            assert!(c.entry(w[0]).power_uw() <= c.entry(w[1]).power_uw());
        }
        // sorted-by-metric really is sorted
        let order = c.sorted_indices(f, Metric::Mae);
        for w in order.windows(2) {
            assert!(c.entry(w[0]).metric(Metric::Mae) <= c.entry(w[1]).metric(Metric::Mae));
        }
    }

    #[test]
    fn get_binary_search_finds_every_id() {
        let lib = small_library();
        let c = CompiledLibrary::from_bytes(compile_library(&lib)).unwrap();
        for e in lib.entries() {
            assert_eq!(c.get(&e.id).unwrap().id(), e.id);
        }
        assert!(c.get("mul8u_ZZZZ").is_none());
        assert!(c.get("").is_none());
    }

    #[test]
    fn empty_library_compiles() {
        let lib = Library::new();
        let c = CompiledLibrary::from_bytes(compile_library(&lib)).unwrap();
        assert!(c.is_empty());
        assert!(c.census_rows().is_empty());
        assert!(c.for_fn_indices(ArithFn::Mul { w: 8 }).is_empty());
        assert!(c.get("anything").is_none());
    }

    #[test]
    fn corruption_is_rejected() {
        let lib = small_library();
        let good = compile_library(&lib);
        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(CompiledLibrary::from_bytes(bad).unwrap_err().contains("magic"));
        // unknown version
        let mut bad = good.clone();
        bad[8] = 99;
        assert!(CompiledLibrary::from_bytes(bad)
            .unwrap_err()
            .contains("version"));
        // truncation
        let mut bad = good.clone();
        bad.truncate(good.len() - 10);
        assert!(CompiledLibrary::from_bytes(bad)
            .unwrap_err()
            .contains("truncated"));
        // payload bit flip → checksum mismatch
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        assert!(CompiledLibrary::from_bytes(bad)
            .unwrap_err()
            .contains("checksum"));
        // header section table pointing past the payload must be caught by
        // bounds validation (the checksum covers only the payload)
        let mut bad = good.clone();
        bad[48..56].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = CompiledLibrary::from_bytes(bad).unwrap_err();
        assert!(err.contains("section") || err.contains("overflow"), "{err}");
        // shorter than the header
        assert!(CompiledLibrary::from_bytes(b"EVOAPXL1".to_vec())
            .unwrap_err()
            .contains("header"));
        // the pristine bytes still load
        assert!(CompiledLibrary::from_bytes(good).is_ok());
    }
}
