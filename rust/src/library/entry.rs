//! One library entry: a characterised approximate circuit.

use crate::circuit::analysis::{verify_netlist, with_shared_engine, StaticBounds};
use crate::circuit::cost::{CircuitCost, CostModel};
use crate::circuit::gate::GateKind;
use crate::circuit::netlist::{Netlist, Node};
use crate::circuit::simulator::{
    activity_exhaustive, activity_vectors, activity_vectors_wide, with_shared_sim,
};
use crate::circuit::verify::{stratified_vectors, wide_characterisation_vectors, ArithFn};
use crate::cgp::metrics::{ErrorMetrics, RelativeErrors};
use crate::util::json::Json;

/// How an entry came to exist — recorded for reproducibility.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Origin {
    /// Conventional exact implementation (a CGP seed).
    Seed(String),
    /// Evolved by CGP: `(metric, e_max, seed)`.
    Evolved { metric: String, e_max_permille: u64, seed: u64 },
    /// Operand truncation to `keep` bits.
    Truncated { keep: u32 },
    /// Broken-array multiplier with break levels `(h, v)`.
    Bam { h: u32, v: u32 },
}

impl Origin {
    /// Evolved-origin constructor with a clamped budget: wide (up to
    /// 256-output) functions have astronomical absolute `e_max` values,
    /// and the JSON layer stores numbers as `f64` and reads integers back
    /// only below 9e15 — so cap the permille at 2⁵² to keep the library
    /// round trip lossless instead of saturating to `u64::MAX` (which
    /// serialised as `-1` and made reloads fail).
    pub fn evolved(metric: &str, e_max: f64, seed: u64) -> Origin {
        Origin::Evolved {
            metric: metric.to_string(),
            e_max_permille: (e_max * 1000.0).min((1u64 << 52) as f64) as u64,
            seed,
        }
    }

    /// Serialise.
    pub fn to_json(&self) -> Json {
        match self {
            Origin::Seed(s) => Json::obj([("kind", "seed".into()), ("name", s.as_str().into())]),
            Origin::Evolved {
                metric,
                e_max_permille,
                seed,
            } => Json::obj([
                ("kind", "evolved".into()),
                ("metric", metric.as_str().into()),
                ("e_max_permille", (*e_max_permille as i64).into()),
                ("seed", (*seed as i64).into()),
            ]),
            Origin::Truncated { keep } => {
                Json::obj([("kind", "truncated".into()), ("keep", (*keep).into())])
            }
            Origin::Bam { h, v } => Json::obj([
                ("kind", "bam".into()),
                ("h", (*h).into()),
                ("v", (*v).into()),
            ]),
        }
    }

    /// Deserialise.
    pub fn from_json(j: &Json) -> Result<Origin, String> {
        match j.req_str("kind")? {
            "seed" => Ok(Origin::Seed(j.req_str("name")?.to_string())),
            "evolved" => Ok(Origin::Evolved {
                metric: j.req_str("metric")?.to_string(),
                e_max_permille: j.req_i64("e_max_permille")? as u64,
                seed: j.req_i64("seed")? as u64,
            }),
            "truncated" => Ok(Origin::Truncated {
                keep: j.req_i64("keep")? as u32,
            }),
            "bam" => Ok(Origin::Bam {
                h: j.req_i64("h")? as u32,
                v: j.req_i64("v")? as u32,
            }),
            k => Err(format!("unknown origin kind `{k}`")),
        }
    }

    /// Recover the provenance of a built-in baseline netlist from its
    /// generator-assigned name (`mul8u_trunc7`, `mul8u_bam_h0_v6`, …).
    /// Anything unrecognised is recorded as a seed.
    pub fn from_baseline_name(name: &str) -> Origin {
        if let Some(rest) = name.strip_prefix("mul8u_trunc") {
            Origin::Truncated {
                keep: rest.parse().unwrap_or(0),
            }
        } else if name.contains("bam") {
            let h = name
                .split("_h")
                .nth(1)
                .and_then(|s| s.split('_').next())
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let v = name
                .split("_v")
                .nth(1)
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            Origin::Bam { h, v }
        } else {
            Origin::Seed(name.to_string())
        }
    }

    /// Short human label (Table II first column style).
    pub fn label(&self) -> String {
        match self {
            Origin::Seed(s) => format!("exact ({s})"),
            Origin::Evolved { .. } => "evolved".to_string(),
            Origin::Truncated { keep } => format!("Truncated {keep}-bit"),
            Origin::Bam { h, v } => format!("BAM h={h} v={v}"),
        }
    }
}

/// A fully characterised approximate (or exact) arithmetic circuit.
#[derive(Debug, Clone)]
pub struct Entry {
    /// Stable id, e.g. `mul8u_03F2` (tag + functional hash).
    pub id: String,
    /// Target arithmetic function.
    pub f: ArithFn,
    /// The circuit itself (compacted).
    pub netlist: Netlist,
    /// All six error metrics (eqs. 1–6).
    pub metrics: ErrorMetrics,
    /// The metrics as Table-II-style percentages.
    pub rel: RelativeErrors,
    /// Synthesis-model characterisation.
    pub cost: CircuitCost,
    /// Provable static error bounds (`circuit::analysis`) — sound
    /// companions to the (possibly sampled) `metrics`.
    pub bounds: StaticBounds,
    /// Provenance.
    pub origin: Origin,
}

impl Entry {
    /// Characterise a netlist into an entry: functional hash id, all six
    /// metrics, activity-based power — exhaustively when feasible, over the
    /// deterministic stratified sample otherwise (multi-word packed beyond
    /// 32-bit operands).
    pub fn characterise(
        netlist: Netlist,
        f: ArithFn,
        model: &CostModel,
        origin: Origin,
    ) -> Entry {
        let netlist = netlist.compact();
        let (metrics, cost, hash) = if f.exhaustive_feasible() {
            let (table, act) = activity_exhaustive(&netlist);
            let metrics = ErrorMetrics::vs_exact_table(&table, f);
            let cost = model.evaluate(&netlist, &act);
            (metrics, cost, fnv1a(table.iter().copied()))
        } else if f.is_narrow() {
            let vecs = stratified_vectors(f, 16, 0x11B);
            let (outs, act) = activity_vectors(&netlist, &vecs);
            let metrics = ErrorMetrics::vs_exact_sampled(&vecs, &outs, f);
            let cost = model.evaluate(&netlist, &act);
            (metrics, cost, fnv1a(outs.iter().copied()))
        } else {
            let vecs = wide_characterisation_vectors(f);
            let (outs, act) = activity_vectors_wide(&netlist, &vecs);
            let metrics = ErrorMetrics::vs_exact_wide_sampled(&vecs, &outs, f);
            let cost = model.evaluate(&netlist, &act);
            (metrics, cost, fnv1a(outs.iter().flat_map(|v| v.words())))
        };
        let rel = metrics.as_percentages(f);
        let bounds = with_shared_engine(f, |eng| eng.bounds(&netlist))
            .unwrap_or_else(|| StaticBounds::vacuous(f));
        let id = format!("{}_{:04X}", f.tag(), hash & 0xFFFF);
        let mut netlist = netlist;
        netlist.name = id.clone();
        Entry {
            id,
            f,
            netlist,
            metrics,
            rel,
            cost,
            bounds,
            origin,
        }
    }

    /// Functional hash — same id ⇔ same behaviour on the evaluation set.
    /// Hashes straight out of the per-thread simulator scratch: no result
    /// copy, no per-call `BitSim` allocation.
    pub fn functional_hash(&self) -> u64 {
        if self.f.exhaustive_feasible() {
            with_shared_sim(|sim| fnv1a(sim.eval_exhaustive(&self.netlist).iter().copied()))
        } else if self.f.is_narrow() {
            let vecs = stratified_vectors(self.f, 16, 0x11B);
            with_shared_sim(|sim| fnv1a(sim.eval_vectors(&self.netlist, &vecs).iter().copied()))
        } else {
            let vecs = wide_characterisation_vectors(self.f);
            with_shared_sim(|sim| {
                fnv1a(
                    sim.eval_vectors_wide(&self.netlist, &vecs)
                        .iter()
                        .flat_map(|v| v.words()),
                )
            })
        }
    }

    /// Serialise the whole entry (including the netlist).
    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .netlist
            .nodes
            .iter()
            .map(|n| {
                Json::Arr(vec![
                    (n.kind.code() as i64).into(),
                    (n.a as i64).into(),
                    (n.b as i64).into(),
                ])
            })
            .collect();
        let outputs: Vec<Json> = self
            .netlist
            .outputs
            .iter()
            .map(|&o| (o as i64).into())
            .collect();
        Json::obj([
            ("id", self.id.as_str().into()),
            ("fn", self.f.tag().into()),
            ("width", self.f.width().into()),
            (
                "is_mul",
                matches!(self.f, ArithFn::Mul { .. }).into(),
            ),
            ("n_inputs", self.netlist.n_inputs.into()),
            ("nodes", Json::Arr(nodes)),
            ("outputs", Json::Arr(outputs)),
            (
                "metrics",
                Json::obj([
                    ("er", self.metrics.er.into()),
                    ("mae", self.metrics.mae.into()),
                    ("mse", self.metrics.mse.into()),
                    ("mre", self.metrics.mre.into()),
                    ("wce", self.metrics.wce.into()),
                    ("wcre", self.metrics.wcre.into()),
                    ("n_vectors", (self.metrics.n_vectors as i64).into()),
                    ("exhaustive", self.metrics.exhaustive.into()),
                ]),
            ),
            (
                "cost",
                Json::obj([
                    ("gates", self.cost.gates.into()),
                    ("area_um2", self.cost.area_um2.into()),
                    ("delay_ps", self.cost.delay_ps.into()),
                    ("leakage_uw", self.cost.leakage_uw.into()),
                    ("dynamic_uw", self.cost.dynamic_uw.into()),
                    ("power_uw", self.cost.power_uw.into()),
                ]),
            ),
            (
                "bounds",
                Json::obj([
                    ("wce_bound", self.bounds.wce_bound.into()),
                    ("mae_bound", self.bounds.mae_bound.into()),
                    ("wce_floor", self.bounds.wce_floor.into()),
                    ("exact_proven", self.bounds.exact_proven.into()),
                ]),
            ),
            ("origin", self.origin.to_json()),
        ])
    }

    /// Deserialise (recomputes the Table-II percentage view).
    pub fn from_json(j: &Json) -> Result<Entry, String> {
        let width = j.req_i64("width")? as u32;
        let f = if j.req("is_mul")?.as_bool().unwrap_or(false) {
            ArithFn::Mul { w: width }
        } else {
            ArithFn::Add { w: width }
        }
        .validated()?;
        let n_inputs = j.req_i64("n_inputs")? as u32;
        let mut netlist = Netlist::new(n_inputs, j.req_str("id")?);
        for n in j.req_arr("nodes")? {
            let t = n.as_arr().ok_or("node not an array")?;
            if t.len() != 3 {
                return Err("node arity".into());
            }
            let kind = GateKind::from_code(t[0].as_i64().ok_or("code")? as u8)
                .ok_or("bad gate code")?;
            netlist.nodes.push(Node {
                kind,
                a: t[1].as_i64().ok_or("a")? as u32,
                b: t[2].as_i64().ok_or("b")? as u32,
            });
        }
        for o in j.req_arr("outputs")? {
            netlist.outputs.push(o.as_i64().ok_or("output")? as u32);
        }
        // Validate through the static analyzer at the ingest boundary:
        // forward operand references, out-of-range outputs and shape
        // mismatches become proper errors here instead of simulator
        // panics downstream.
        let report = verify_netlist(&netlist);
        if let Some(v) = report.violations.first() {
            return Err(format!("invalid netlist `{}`: {v}", netlist.name));
        }
        if netlist.n_inputs != f.n_inputs() || netlist.n_outputs() != f.n_outputs() {
            return Err(format!(
                "invalid netlist `{}`: {} inputs / {} outputs, {} needs {} / {}",
                netlist.name,
                netlist.n_inputs,
                netlist.n_outputs(),
                f.tag(),
                f.n_inputs(),
                f.n_outputs()
            ));
        }
        // Pre-bounds libraries (no `bounds` object) get provable bounds
        // recomputed on load; fresh libraries round-trip them verbatim.
        let bounds = match j.get("bounds") {
            Some(b) => StaticBounds {
                wce_bound: b.req_f64("wce_bound")?,
                mae_bound: b.req_f64("mae_bound")?,
                wce_floor: b.req_f64("wce_floor")?,
                exact_proven: b.req("exact_proven")?.as_bool().unwrap_or(false),
            },
            None => with_shared_engine(f, |eng| eng.bounds(&netlist))
                .unwrap_or_else(|| StaticBounds::vacuous(f)),
        };
        let m = j.req("metrics")?;
        let metrics = ErrorMetrics {
            er: m.req_f64("er")?,
            mae: m.req_f64("mae")?,
            mse: m.req_f64("mse")?,
            mre: m.req_f64("mre")?,
            wce: m.req_f64("wce")?,
            wcre: m.req_f64("wcre")?,
            n_vectors: m.req_i64("n_vectors")? as u64,
            exhaustive: m.req("exhaustive")?.as_bool().unwrap_or(false),
        };
        let c = j.req("cost")?;
        let cost = CircuitCost {
            gates: c.req_i64("gates")? as usize,
            area_um2: c.req_f64("area_um2")?,
            delay_ps: c.req_f64("delay_ps")?,
            leakage_uw: c.req_f64("leakage_uw")?,
            dynamic_uw: c.req_f64("dynamic_uw")?,
            power_uw: c.req_f64("power_uw")?,
        };
        Ok(Entry {
            id: j.req_str("id")?.to_string(),
            f,
            rel: metrics.as_percentages(f),
            netlist,
            metrics,
            cost,
            bounds,
            origin: Origin::from_json(j.req("origin")?)?,
        })
    }
}

/// FNV-1a over a u64 stream.
pub fn fnv1a(values: impl Iterator<Item = u64>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in values {
        for byte in v.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::baselines::{bam_multiplier, truncated_multiplier};
    use crate::circuit::generators::wallace_multiplier;

    #[test]
    fn characterise_exact_seed() {
        let model = CostModel::default();
        let e = Entry::characterise(
            wallace_multiplier(8),
            ArithFn::Mul { w: 8 },
            &model,
            Origin::Seed("wallace".into()),
        );
        assert_eq!(e.metrics.er, 0.0);
        assert!(e.cost.power_uw > 0.0);
        assert!(e.id.starts_with("mul8u_"));
    }

    #[test]
    fn json_round_trip() {
        let model = CostModel::default();
        let e = Entry::characterise(
            bam_multiplier(8, 1, 3),
            ArithFn::Mul { w: 8 },
            &model,
            Origin::Bam { h: 1, v: 3 },
        );
        let j = e.to_json();
        let e2 = Entry::from_json(&j).unwrap();
        assert_eq!(e2.id, e.id);
        assert_eq!(e2.netlist, e.netlist);
        assert_eq!(e2.metrics.mae, e.metrics.mae);
        assert_eq!(e2.cost.power_uw, e.cost.power_uw);
        assert_eq!(e2.origin, e.origin);
        // functional hash must survive the round trip
        assert_eq!(e2.functional_hash(), e.functional_hash());
    }

    #[test]
    fn same_function_same_id() {
        let model = CostModel::default();
        let a = Entry::characterise(
            truncated_multiplier(8, 8),
            ArithFn::Mul { w: 8 },
            &model,
            Origin::Truncated { keep: 8 },
        );
        let b = Entry::characterise(
            wallace_multiplier(8),
            ArithFn::Mul { w: 8 },
            &model,
            Origin::Seed("wallace".into()),
        );
        // both are exact 8-bit multipliers → identical functional hash/id
        assert_eq!(a.id, b.id);
    }

    #[test]
    fn characterise_wide_adder_sampled() {
        use crate::circuit::generators::ripple_carry_adder;
        let model = CostModel::default();
        let f = ArithFn::Add { w: 33 };
        let e = Entry::characterise(
            ripple_carry_adder(33),
            f,
            &model,
            Origin::Seed("rca33".into()),
        );
        assert!(e.metrics.verified_exact(), "exact rca must sample clean");
        assert!(!e.metrics.exhaustive);
        assert!(e.metrics.n_vectors > 0);
        assert!(e.id.starts_with("add33u_"), "{}", e.id);
        assert!(e.cost.power_uw > 0.0);
        // JSON round trip keeps the wide functional hash stable
        let e2 = Entry::from_json(&e.to_json()).unwrap();
        assert_eq!(e2.functional_hash(), e.functional_hash());
        assert_eq!(e2.f, f);
    }

    #[test]
    fn from_json_rejects_unrepresentable_width() {
        let text = r#"{"id":"mul300u_0000","fn":"mul300u","width":300,
            "is_mul":true,"n_inputs":600,"nodes":[],"outputs":[],
            "metrics":{"er":0,"mae":0,"mse":0,"mre":0,"wce":0,"wcre":0,
                       "n_vectors":1,"exhaustive":false},
            "cost":{"gates":0,"area_um2":0,"delay_ps":0,"leakage_uw":0,
                    "dynamic_uw":0,"power_uw":0},
            "origin":{"kind":"seed","name":"x"}}"#;
        let j = Json::parse(text).unwrap();
        let err = Entry::from_json(&j).unwrap_err();
        assert!(err.contains("128"), "{err}");
    }

    #[test]
    fn evolved_origin_clamps_wide_budgets_for_json() {
        // a 128-bit multiplier's MAE budget is ~1e75 — permille must clamp
        // below the JSON integer ceiling instead of saturating/wrapping
        let o = Origin::evolved("MAE", 1e75, 7);
        let Origin::Evolved { e_max_permille, .. } = &o else {
            panic!("wrong variant");
        };
        assert_eq!(*e_max_permille, 1u64 << 52);
        let j = o.to_json();
        assert!(j.req_i64("e_max_permille").unwrap() > 0);
        assert_eq!(Origin::from_json(&j).unwrap(), o);
        // small budgets stay exact
        let Origin::Evolved { e_max_permille, .. } = Origin::evolved("WCE", 2.5, 1) else {
            panic!("wrong variant");
        };
        assert_eq!(e_max_permille, 2500);
    }

    #[test]
    fn origin_labels() {
        assert_eq!(Origin::Truncated { keep: 7 }.label(), "Truncated 7-bit");
        assert_eq!(Origin::Bam { h: 0, v: 2 }.label(), "BAM h=0 v=2");
    }
}
