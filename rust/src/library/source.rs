//! [`LibrarySource`]: the Json | Compiled abstraction every read-only
//! consumer of a library holds (DESIGN.md §10).
//!
//! A source answers the hot queries — census, Pareto front, `get`,
//! `for_fn`, diverse selection — from whichever representation it wraps:
//! a fully-owned JSON-loaded [`Library`], or a [`CompiledLibrary`] slab
//! whose precomputed indices answer them without deserialising untouched
//! entries. The two paths are byte-identical by construction: the compiler
//! runs the very same `census_rows`/`pareto_indices` code the JSON path
//! runs per query and freezes the result, and the compiled `select_diverse`
//! replays the JSON selection procedure operation for operation over the
//! frozen fronts. Only mutation paths (evolve/ingest) need the owned form
//! — they keep taking `&mut Library` and recompile afterwards.

use std::path::Path;

use crate::cgp::metrics::Metric;
use crate::circuit::verify::ArithFn;

use super::compiled::{compile_library, CompiledLibrary, Fnv64, MAGIC};
use super::selection::{evenly_by_power, pareto_indices};
use super::store::{CensusRow, Library};
use super::Entry;

enum Inner {
    Json(Library),
    Compiled(CompiledLibrary),
}

/// A read-only library backend: `Json` (owned entries) or `Compiled`
/// (zero-copy slab with precomputed indices). See the module docs.
pub struct LibrarySource {
    inner: Inner,
    fingerprint: u64,
}

impl From<Library> for LibrarySource {
    fn from(lib: Library) -> LibrarySource {
        let mut h = Fnv64::new();
        h.write(&(lib.len() as u64).to_le_bytes());
        for e in lib.entries() {
            h.write(e.id.as_bytes());
            h.write(&[0]); // id terminator: no ambiguity between adjacent ids
            h.write(&e.f.width().to_le_bytes());
            h.write(&e.cost.power_uw.to_bits().to_le_bytes());
        }
        LibrarySource {
            fingerprint: h.finish(),
            inner: Inner::Json(lib),
        }
    }
}

impl From<CompiledLibrary> for LibrarySource {
    fn from(lib: CompiledLibrary) -> LibrarySource {
        LibrarySource {
            fingerprint: lib.fingerprint(),
            inner: Inner::Compiled(lib),
        }
    }
}

impl std::fmt::Debug for LibrarySource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.inner {
            Inner::Json(_) => "Json",
            Inner::Compiled(_) => "Compiled",
        };
        f.debug_struct("LibrarySource")
            .field("kind", &kind)
            .field("entries", &self.len())
            .finish()
    }
}

impl LibrarySource {
    /// Open a library file, sniffing the format: a compiled-store magic
    /// prefix loads the zero-copy slab, anything else parses as JSON.
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<LibrarySource> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        if bytes.len() >= MAGIC.len() && bytes[..MAGIC.len()] == MAGIC {
            let compiled = CompiledLibrary::from_bytes(bytes)
                .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
            return Ok(LibrarySource::from(compiled));
        }
        let text = String::from_utf8(bytes)
            .map_err(|_| anyhow::anyhow!("{}: neither compiled store nor UTF-8 JSON", path.display()))?;
        let lib = Library::from_json_str(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Ok(LibrarySource::from(lib))
    }

    /// The built-in Table-II baseline library, as a source.
    pub fn baseline() -> LibrarySource {
        LibrarySource::from(Library::baseline())
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        match &self.inner {
            Inner::Json(l) => l.len(),
            Inner::Compiled(c) => c.len(),
        }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True for the compiled backend.
    pub fn is_compiled(&self) -> bool {
        matches!(self.inner, Inner::Compiled(_))
    }

    /// Content fingerprint: the payload checksum for compiled stores, an
    /// id/width/power digest for JSON libraries. Cache keys hang off this.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// The owned library, when this source is JSON-backed.
    pub fn as_json(&self) -> Option<&Library> {
        match &self.inner {
            Inner::Json(l) => Some(l),
            Inner::Compiled(_) => None,
        }
    }

    /// Compile this source to the binary format (no-op re-encode for an
    /// already-compiled slab is avoided: compiled sources round-trip
    /// through materialisation only when explicitly asked).
    pub fn compile(&self) -> Vec<u8> {
        match &self.inner {
            Inner::Json(l) => compile_library(l),
            Inner::Compiled(c) => {
                let mut lib = Library::new();
                for i in 0..c.len() {
                    lib.insert(c.entry(i).materialise());
                }
                compile_library(&lib)
            }
        }
    }

    /// `(kind, width, count)` census triples (CLI `census` output).
    pub fn census(&self) -> Vec<(String, u32, usize)> {
        match &self.inner {
            Inner::Json(l) => l.census(),
            Inner::Compiled(c) => c
                .census_rows()
                .into_iter()
                .map(|r| (r.kind, r.width, r.count))
                .collect(),
        }
    }

    /// Full census rows — precomputed for compiled stores.
    pub fn census_rows(&self) -> Vec<CensusRow> {
        match &self.inner {
            Inner::Json(l) => l.census_rows(),
            Inner::Compiled(c) => c.census_rows(),
        }
    }

    /// Owned copies of the entries implementing `f`, insertion order.
    pub fn for_fn(&self, f: ArithFn) -> Vec<Entry> {
        match &self.inner {
            Inner::Json(l) => l.for_fn(f).into_iter().cloned().collect(),
            Inner::Compiled(c) => c
                .for_fn_indices(f)
                .into_iter()
                .map(|i| c.entry(i).materialise())
                .collect(),
        }
    }

    /// Number of entries implementing `f` — no materialisation either way.
    pub fn for_fn_len(&self, f: ArithFn) -> usize {
        match &self.inner {
            Inner::Json(l) => l.for_fn(f).len(),
            Inner::Compiled(c) => c.for_fn_len(f),
        }
    }

    /// Owned copy of entry `i` in storage order — insertion order for the
    /// JSON backend, record order for the compiled one; the two coincide
    /// by construction (the compiler writes records in insertion order).
    /// `None` when out of range. The `library analyze` walk uses this.
    pub fn entry_at(&self, i: usize) -> Option<Entry> {
        match &self.inner {
            Inner::Json(l) => l.entries().get(i).cloned(),
            Inner::Compiled(c) => (i < c.len()).then(|| c.entry(i).materialise()),
        }
    }

    /// Entry by id.
    pub fn get(&self, id: &str) -> Option<Entry> {
        match &self.inner {
            Inner::Json(l) => l.get(id).cloned(),
            Inner::Compiled(c) => c.get(id).map(|v| v.materialise()),
        }
    }

    /// The (power, `metric`) Pareto front of `f`: `(population, front)`
    /// with the front in insertion order — derived per call on the JSON
    /// path, read off the precomputed FNTAB section on the compiled path.
    pub fn pareto_front(&self, f: ArithFn, metric: Metric) -> (usize, Vec<Entry>) {
        match &self.inner {
            Inner::Json(l) => {
                let all = l.for_fn(f);
                let front = pareto_indices(&all, metric)
                    .into_iter()
                    .map(|i| all[i].clone())
                    .collect();
                (all.len(), front)
            }
            Inner::Compiled(c) => (
                c.for_fn_len(f),
                c.front_indices(f, metric)
                    .into_iter()
                    .map(|i| c.entry(i).materialise())
                    .collect(),
            ),
        }
    }

    /// The §IV diverse selection (see `selection::select_diverse`), owned.
    ///
    /// The compiled arm replays the JSON procedure operation for
    /// operation — per-metric precomputed front → `evenly_by_power` →
    /// id-dedup union → descending-power sort — so both backends return
    /// the same entries in the same order.
    pub fn select_diverse(&self, f: ArithFn, metrics: &[Metric], k: usize) -> Vec<Entry> {
        match &self.inner {
            Inner::Json(l) => super::selection::select_diverse(l, f, metrics, k)
                .into_iter()
                .cloned()
                .collect(),
            Inner::Compiled(c) => {
                let mut chosen: Vec<Entry> = Vec::new();
                for &m in metrics {
                    let front: Vec<Entry> = c
                        .front_indices(f, m)
                        .into_iter()
                        .map(|i| c.entry(i).materialise())
                        .collect();
                    let refs: Vec<&Entry> = front.iter().collect();
                    for e in evenly_by_power(&refs, k) {
                        if !chosen.iter().any(|ch| ch.id == e.id) {
                            chosen.push(e.clone());
                        }
                    }
                }
                chosen.sort_by(|a, b| b.cost.power_uw.total_cmp(&a.cost.power_uw));
                chosen
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgp::metrics::SELECTION_METRICS;

    fn both_sources() -> (LibrarySource, LibrarySource) {
        let lib = Library::baseline();
        let compiled =
            CompiledLibrary::from_bytes(compile_library(&lib)).expect("baseline compiles");
        (LibrarySource::from(lib), LibrarySource::from(compiled))
    }

    #[test]
    fn open_sniffs_both_formats() {
        let dir = std::env::temp_dir().join("evoapprox_test_source");
        std::fs::create_dir_all(&dir).unwrap();
        let lib = Library::baseline();
        let json_path = dir.join("lib.json");
        lib.save(&json_path).unwrap();
        let bin_path = dir.join("lib.bin");
        std::fs::write(&bin_path, compile_library(&lib)).unwrap();

        let json_src = LibrarySource::open(&json_path).unwrap();
        let bin_src = LibrarySource::open(&bin_path).unwrap();
        assert!(!json_src.is_compiled());
        assert!(bin_src.is_compiled());
        assert_eq!(json_src.len(), lib.len());
        assert_eq!(bin_src.len(), lib.len());
        assert_eq!(json_src.census_rows(), bin_src.census_rows());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn query_surface_is_backend_identical() {
        let (json, bin) = both_sources();
        assert_eq!(json.len(), bin.len());
        assert_eq!(json.census(), bin.census());
        assert_eq!(json.census_rows(), bin.census_rows());
        let f = ArithFn::Mul { w: 8 };
        assert_eq!(json.for_fn_len(f), bin.for_fn_len(f));

        let a = json.for_fn(f);
        let b = bin.for_fn(f);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.netlist, y.netlist);
            assert_eq!(x.metrics, y.metrics);
            assert_eq!(x.cost, y.cost);
            assert_eq!(x.rel, y.rel);
            assert_eq!(x.origin, y.origin);
            assert_eq!(x.bounds, y.bounds);
        }

        // storage-order walk agrees across backends and with for_fn order
        for i in 0..json.len() {
            let e1 = json.entry_at(i).unwrap();
            let e2 = bin.entry_at(i).unwrap();
            assert_eq!(e1.id, e2.id);
            assert_eq!(e1.bounds, e2.bounds);
        }
        assert!(json.entry_at(json.len()).is_none());
        assert!(bin.entry_at(bin.len()).is_none());

        for e in &a {
            let g1 = json.get(&e.id).unwrap();
            let g2 = bin.get(&e.id).unwrap();
            assert_eq!(g1.id, g2.id);
            assert_eq!(g1.cost, g2.cost);
        }
        assert!(json.get("nope").is_none());
        assert!(bin.get("nope").is_none());

        for m in [Metric::Mae, Metric::Wce, Metric::Er] {
            let (p1, f1) = json.pareto_front(f, m);
            let (p2, f2) = bin.pareto_front(f, m);
            assert_eq!(p1, p2);
            let ids1: Vec<&str> = f1.iter().map(|e| e.id.as_str()).collect();
            let ids2: Vec<&str> = f2.iter().map(|e| e.id.as_str()).collect();
            assert_eq!(ids1, ids2, "{m:?}");
        }

        let s1 = json.select_diverse(f, &SELECTION_METRICS, 10);
        let s2 = bin.select_diverse(f, &SELECTION_METRICS, 10);
        let ids1: Vec<&str> = s1.iter().map(|e| e.id.as_str()).collect();
        let ids2: Vec<&str> = s2.iter().map(|e| e.id.as_str()).collect();
        assert_eq!(ids1, ids2);
    }

    #[test]
    fn fingerprints_are_stable_and_content_sensitive() {
        let (json, bin) = both_sources();
        let (json2, bin2) = both_sources();
        assert_eq!(json.fingerprint(), json2.fingerprint());
        assert_eq!(bin.fingerprint(), bin2.fingerprint());
        // an empty library fingerprints differently from the baseline
        let empty = LibrarySource::from(Library::new());
        assert_ne!(empty.fingerprint(), json.fingerprint());
    }
}
