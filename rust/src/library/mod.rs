//! The approximate-circuit library (§III): characterised entries, JSON
//! persistence, a compiled zero-copy binary store (DESIGN.md §10),
//! Table-I census, Pareto selection (§IV) and the CGP construction
//! campaigns.

pub mod catalog;
pub mod compiled;
pub mod entry;
pub mod selection;
pub mod source;
pub mod store;

pub use catalog::{
    approx_seeds_for, campaign_context, run_campaign, seeds_for, target_ladder, CampaignConfig,
    CampaignProgress,
};
pub use compiled::{compile_library, metric_slot, CompiledLibrary, EntryView, METRIC_ORDER};
pub use entry::{Entry, Origin};
pub use selection::{evenly_by_power, pareto_indices, select_diverse};
pub use source::LibrarySource;
pub use store::{CensusRow, Library};
