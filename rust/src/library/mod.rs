//! The approximate-circuit library (§III): characterised entries, JSON
//! persistence, Table-I census, Pareto selection (§IV) and the CGP
//! construction campaigns.

pub mod catalog;
pub mod entry;
pub mod selection;
pub mod store;

pub use catalog::{
    approx_seeds_for, campaign_context, run_campaign, seeds_for, target_ladder, CampaignConfig,
    CampaignProgress,
};
pub use entry::{Entry, Origin};
pub use selection::{evenly_by_power, pareto_indices, select_diverse};
pub use store::{CensusRow, Library};
