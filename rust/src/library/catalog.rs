//! Library-construction campaigns (§III): seed CGP with conventional
//! circuits, sweep `e_max` target ladders per error metric, harvest every
//! non-dominated candidate along each run, characterise and ingest.
//!
//! The published campaign runs 1 M generations per target for weeks of CPU
//! time; budgets here are configurable and the defaults are scaled for the
//! testbed (DESIGN.md §4 records the substitution).
//!
//! Execution goes through the job pool of [`crate::cgp::campaign`]
//! (DESIGN.md §6): the full metric × e_max × seed grid is expanded into an
//! ordered job list, every job derives its RNG seed from the grid position
//! (never from scheduling), harvest characterisation runs on the workers,
//! and ingestion happens in grid order — so `jobs = 1` and `jobs = N`
//! produce byte-identical libraries.

use crate::cgp::campaign::{run_evolve_jobs, EvolveJob};
use crate::cgp::evaluator::EvalContext;
use crate::cgp::evolve::EvolveConfig;
use crate::cgp::metrics::Metric;
use crate::circuit::cost::CostModel;
use crate::circuit::generators::{
    kogge_stone_adder, ripple_carry_adder, wallace_multiplier,
};
use crate::circuit::netlist::Netlist;
use crate::circuit::verify::{per_stratum_for_budget, ArithFn, WIDE_SEARCH_MAX_VECTORS};

use super::entry::{Entry, Origin};
use super::store::Library;

/// Campaign parameters for one target function.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Target function.
    pub f: ArithFn,
    /// Error metrics to drive runs with.
    pub metrics: Vec<Metric>,
    /// Number of `e_max` targets per metric (log-spaced ladder).
    pub targets_per_metric: u32,
    /// Generations per run.
    pub generations: u64,
    /// Offspring per generation.
    pub lambda: u32,
    /// Genes mutated per offspring.
    pub h: u32,
    /// Slack columns appended to the seed.
    pub slack: u32,
    /// Master seed.
    pub seed: u64,
    /// Per-stratum sample count for wide (non-exhaustive) functions.
    /// On the multi-word (> 32-bit) path it is additionally capped so the
    /// search sample stays within `WIDE_SEARCH_MAX_VECTORS` total vectors.
    pub per_stratum: usize,
    /// Search on a stratified sample even when exhaustive evaluation is
    /// feasible (≈40× more generations per second for 8-bit multipliers;
    /// §Perf L3). Candidates are still characterised *exhaustively* before
    /// entering the library, so entry metrics stay exact.
    pub sampled_search: bool,
    /// Worker threads for the run grid (1 = serial; the library output is
    /// byte-identical for every value).
    pub jobs: usize,
    /// Static-analysis fitness pre-screen: discard mutants whose provable
    /// error floor already exceeds the run's `e_max` without simulating
    /// them (see [`EvolveConfig::prescreen`]). Deterministic and sound —
    /// never discards a feasible candidate — but off by default because it
    /// changes how infeasible candidates rank during the search.
    pub prescreen: bool,
}

impl CampaignConfig {
    /// Scaled default campaign for `f` (paper: λ=1, h=5, 1 M generations;
    /// we default to far fewer generations and λ=4 to use the early-abort
    /// evaluator efficiently — see DESIGN.md §4).
    pub fn quick(f: ArithFn) -> CampaignConfig {
        CampaignConfig {
            f,
            metrics: vec![Metric::Mae, Metric::Wce, Metric::Er],
            targets_per_metric: 4,
            generations: 3_000,
            lambda: 4,
            h: 5,
            slack: 16,
            seed: 0x5EED,
            per_stratum: 24,
            sampled_search: true,
            jobs: 1,
            prescreen: false,
        }
    }
}

/// The `e_max` target ladder for a metric on function `f`: log-spaced
/// fractions of the metric's natural scale.
pub fn target_ladder(f: ArithFn, metric: Metric, n: u32) -> Vec<f64> {
    // in f64 from the start: `(1u128 << n_outputs) - 1` panics (debug) or
    // wraps (release) at the 128 outputs of a 64-bit multiplier
    let max_out = (f.n_outputs() as f64).exp2() - 1.0;
    let (lo, hi) = match metric {
        // fractions of max output value
        Metric::Mae => (1e-5 * max_out, 2e-2 * max_out),
        Metric::Wce => (1e-4 * max_out, 1e-1 * max_out),
        Metric::Mse => (1e-8 * max_out * max_out, 1e-3 * max_out * max_out),
        // plain ratios
        Metric::Er => (0.02, 0.98),
        Metric::Mre => (1e-3, 0.5),
        Metric::Wcre => (1e-2, 4.0),
    };
    if n <= 1 {
        return vec![hi];
    }
    (0..n)
        .map(|i| lo * (hi / lo).powf(i as f64 / (n - 1) as f64))
        .collect()
}

/// Conventional seeds for `f` (§III seeds CGP with exact implementations).
pub fn seeds_for(f: ArithFn) -> Vec<Netlist> {
    match f {
        ArithFn::Add { w } => vec![ripple_carry_adder(w), kogge_stone_adder(w)],
        ArithFn::Mul { w } => vec![wallace_multiplier(w)],
    }
}

/// Approximate seeds for multiplier campaigns — §II-B2: "the search
/// algorithm can start with either a randomly generated initial population
/// or existing designs". Starting some runs from the conventional
/// approximate designs (truncation / BAM) lets the search explore the
/// mid-power region directly instead of having to rediscover those
/// structures from the exact seed, which the published library's week-long
/// runs could afford but a scaled budget cannot.
pub fn approx_seeds_for(f: ArithFn) -> Vec<Netlist> {
    match f {
        ArithFn::Add { .. } => Vec::new(),
        ArithFn::Mul { w } => vec![
            crate::circuit::baselines::truncated_multiplier(w, w - 1),
            crate::circuit::baselines::truncated_multiplier(w, w.saturating_sub(2).max(1)),
            crate::circuit::baselines::bam_multiplier(w, 0, w / 2),
            crate::circuit::baselines::bam_multiplier(w, 1, (3 * w) / 4),
            crate::circuit::baselines::bam_multiplier(w, w / 4, (7 * w) / 8),
        ],
    }
}

/// Progress callback data.
#[derive(Debug, Clone, Copy)]
pub struct CampaignProgress {
    /// Runs finished so far.
    pub runs_done: u32,
    /// Total runs planned.
    pub runs_total: u32,
    /// Entries ingested so far.
    pub entries: usize,
    /// Candidate evaluations performed so far.
    pub evaluations: u64,
}

/// Build the shared evaluation context for a campaign on `cfg.f`.
pub fn campaign_context(cfg: &CampaignConfig) -> EvalContext {
    if cfg.f.exhaustive_feasible() {
        if cfg.sampled_search {
            // unbiased uniform subsample for the search; characterisation
            // is always exhaustive for feasible widths
            EvalContext::uniform_subsample(cfg.f, 81 * cfg.per_stratum, cfg.seed ^ 0xE7A1)
        } else {
            EvalContext::exhaustive(cfg.f)
        }
    } else if cfg.f.is_narrow() {
        EvalContext::sampled(cfg.f, cfg.per_stratum, cfg.seed ^ 0xE7A1)
    } else {
        // wide operands: per_stratum is still honoured, but capped so the
        // search sample stays within the WIDE_SEARCH_MAX_VECTORS budget
        // the CLI evolve path also uses (the full grid would be ≈ (w+1)²·s
        // vectors at 128 bits; the one-draw-per-stratum floor still yields
        // ≈ (w+1)² vectors at the very widest widths — DESIGN.md §4)
        let cap = per_stratum_for_budget(cfg.f, WIDE_SEARCH_MAX_VECTORS);
        EvalContext::sampled(cfg.f, cfg.per_stratum.min(cap).max(1), cfg.seed ^ 0xE7A1)
    }
}

/// Run the campaign across `cfg.jobs` workers, ingesting results into
/// `lib` in deterministic job order. Returns the number of entries added.
pub fn run_campaign(
    lib: &mut Library,
    cfg: &CampaignConfig,
    model: &CostModel,
    mut progress: Option<&mut dyn FnMut(CampaignProgress)>,
) -> usize {
    let mut seeds = seeds_for(cfg.f);
    seeds.extend(approx_seeds_for(cfg.f));
    // widths are validated at ArithFn construction; re-check here so a
    // hand-built config cannot smuggle an unrepresentable width into the
    // job grid (the old ≤64-input assert — the 32-bit width cliff — is
    // gone: wider functions route through the multi-word path)
    if let Err(e) = cfg.f.validated() {
        panic!("run_campaign: {e}");
    }
    // always ingest the exact seeds themselves (approximate run-seeds are
    // NOT ingested here — the baseline set is added by the callers that
    // want it, with proper Truncated/Bam origins)
    let n_exact = seeds_for(cfg.f).len();
    let mut added = 0usize;
    for s in &seeds[..n_exact] {
        let name = s.name.clone();
        if lib.insert(Entry::characterise(
            s.clone(),
            cfg.f,
            model,
            Origin::Seed(name),
        )) {
            added += 1;
        }
    }
    let ctx = campaign_context(cfg);

    // Expand the metric × target × seed grid into an ordered job list. The
    // RNG seed of each run depends only on the grid position, so the sweep
    // is reproducible under any scheduling.
    let mut jobs: Vec<EvolveJob> = Vec::new();
    let mut job_meta: Vec<(Metric, f64, u64)> = Vec::new();
    for (mi, &metric) in cfg.metrics.iter().enumerate() {
        for (ti, &e_max) in target_ladder(cfg.f, metric, cfg.targets_per_metric)
            .iter()
            .enumerate()
        {
            for (si, seed_netlist) in seeds.iter().enumerate() {
                let run_seed = cfg
                    .seed
                    .wrapping_add((mi as u64) << 40)
                    .wrapping_add((ti as u64) << 20)
                    .wrapping_add(si as u64);
                jobs.push(EvolveJob {
                    seed: seed_netlist.clone(),
                    cfg: EvolveConfig {
                        metric,
                        e_min: 0.0,
                        e_max,
                        generations: cfg.generations,
                        lambda: cfg.lambda,
                        h: cfg.h,
                        seed: run_seed,
                        slack: cfg.slack,
                        prescreen: cfg.prescreen,
                    },
                });
                job_meta.push((metric, e_max, run_seed));
            }
        }
    }
    let runs_total = jobs.len() as u32;
    let mut runs_done = 0u32;
    let mut evaluations = 0u64;
    let job_meta = &job_meta;
    run_evolve_jobs(
        &ctx,
        model,
        jobs,
        cfg.jobs,
        // Worker-side: characterise the harvest (the expensive exhaustive
        // re-evaluation) so ingestion on the merge thread stays cheap.
        |i, _job, report| {
            let (metric, e_max, run_seed) = job_meta[i];
            let mut entries: Vec<Entry> = Vec::with_capacity(report.harvest.len());
            for h in report.harvest {
                let entry = Entry::characterise(
                    h.netlist,
                    cfg.f,
                    model,
                    Origin::evolved(metric.name(), e_max, run_seed),
                );
                // skip exact variants (the seeds are already ingested);
                // checked on the characterisation evaluation (exhaustive
                // for feasible widths), since a sampled *search* can
                // report spurious zero error. `verified_exact` also keeps
                // a degenerate empty evaluation (NaN metrics) out of the
                // exact bucket.
                if entry.metrics.verified_exact() {
                    continue;
                }
                entries.push(entry);
            }
            (entries, report.evaluations)
        },
        // Merge-side: invoked strictly in grid order.
        |_, (entries, evals)| {
            evaluations += evals;
            for entry in entries {
                if lib.insert(entry) {
                    added += 1;
                }
            }
            runs_done += 1;
            if let Some(cb) = progress.as_deref_mut() {
                cb(CampaignProgress {
                    runs_done,
                    runs_total,
                    entries: lib.len(),
                    evaluations,
                });
            }
        },
    );
    added
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgp::metrics::SELECTION_METRICS;
    use crate::library::selection::select_diverse;

    #[test]
    fn quick_campaign_populates_library() {
        let f = ArithFn::Mul { w: 4 };
        let mut cfg = CampaignConfig::quick(f);
        cfg.generations = 800;
        cfg.targets_per_metric = 2;
        let model = CostModel::default();
        let mut lib = Library::new();
        let mut calls = 0;
        let added = run_campaign(
            &mut lib,
            &cfg,
            &model,
            Some(&mut |p: CampaignProgress| {
                calls += 1;
                assert!(p.runs_done <= p.runs_total);
            }),
        );
        assert!(added >= 3, "campaign must harvest entries (got {added})");
        assert!(calls > 0);
        // all approximate entries respect their characterised metrics
        for e in lib.entries() {
            assert!(e.metrics.er >= 0.0 && e.metrics.er <= 1.0);
            // degenerate all-constant circuits legally cost zero power
            assert!(e.cost.power_uw >= 0.0);
            if e.metrics.er == 0.0 {
                assert!(e.cost.power_uw > 0.0, "exact circuits need gates");
            }
        }
        // selection works end-to-end on the campaign output
        let sel = select_diverse(&lib, f, &SELECTION_METRICS, 5);
        assert!(!sel.is_empty());
    }

    #[test]
    fn campaign_is_worker_count_invariant() {
        let f = ArithFn::Mul { w: 4 };
        let model = CostModel::default();
        let build = |jobs: usize| {
            let mut cfg = CampaignConfig::quick(f);
            cfg.generations = 300;
            cfg.targets_per_metric = 2;
            cfg.metrics = vec![Metric::Mae, Metric::Wce];
            cfg.jobs = jobs;
            let mut lib = Library::new();
            run_campaign(&mut lib, &cfg, &model, None);
            lib.to_json().to_string()
        };
        let serial = build(1);
        let parallel = build(4);
        assert!(!serial.is_empty());
        assert_eq!(serial, parallel, "library JSON must not depend on --jobs");
    }

    #[test]
    fn target_ladders_are_monotone() {
        for metric in [
            Metric::Er,
            Metric::Mae,
            Metric::Mse,
            Metric::Mre,
            Metric::Wce,
            Metric::Wcre,
        ] {
            let l = target_ladder(ArithFn::Mul { w: 8 }, metric, 6);
            assert_eq!(l.len(), 6);
            for w in l.windows(2) {
                assert!(w[1] > w[0], "{metric:?} ladder not increasing");
            }
        }
    }

    #[test]
    fn target_ladder_survives_128_output_functions() {
        for f in [
            ArithFn::Mul { w: 64 },  // 128 outputs — the old panic site
            ArithFn::Mul { w: 128 }, // 256 outputs
            ArithFn::Add { w: 128 },
        ] {
            for metric in [Metric::Mae, Metric::Wce, Metric::Mse, Metric::Er] {
                let l = target_ladder(f, metric, 5);
                assert_eq!(l.len(), 5);
                assert!(
                    l.iter().all(|v| v.is_finite() && *v > 0.0),
                    "{metric:?} ladder degenerate at {}",
                    f.tag()
                );
                for pair in l.windows(2) {
                    assert!(pair[1] > pair[0]);
                }
            }
        }
    }

    #[test]
    fn seeds_cover_functions() {
        assert_eq!(seeds_for(ArithFn::Add { w: 8 }).len(), 2);
        assert_eq!(seeds_for(ArithFn::Mul { w: 8 }).len(), 1);
    }
}
