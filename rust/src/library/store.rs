//! The library itself: a deduplicated collection of characterised entries
//! with JSON persistence and Table-I-style census reporting.

use std::collections::BTreeMap;
use std::path::Path;

use crate::circuit::verify::ArithFn;
use crate::util::json::Json;

use super::entry::Entry;

/// A library of approximate arithmetic circuits (the EvoApproxLib analogue).
#[derive(Debug, Default)]
pub struct Library {
    entries: Vec<Entry>,
}

impl Library {
    /// Empty library.
    pub fn new() -> Library {
        Library::default()
    }

    /// Insert, deduplicating on `(function, functional hash)` — two circuits
    /// computing the same function keep only the *cheaper* one (by power),
    /// mirroring how the published library keeps distinct behaviours.
    /// Returns `true` if the entry was added or replaced an existing one.
    pub fn insert(&mut self, e: Entry) -> bool {
        if let Some(existing) = self
            .entries
            .iter_mut()
            .find(|x| x.f == e.f && x.id == e.id)
        {
            if e.cost.power_uw < existing.cost.power_uw {
                *existing = e;
                return true;
            }
            return false;
        }
        self.entries.push(e);
        true
    }

    /// All entries.
    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    /// Entries implementing `f`.
    pub fn for_fn(&self, f: ArithFn) -> Vec<&Entry> {
        self.entries.iter().filter(|e| e.f == f).collect()
    }

    /// Find by id.
    pub fn get(&self, id: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.id == id)
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Census per `(circuit kind, bit width)` — the data of Table I.
    pub fn census(&self) -> Vec<(String, u32, usize)> {
        let mut map: BTreeMap<(String, u32), usize> = BTreeMap::new();
        for e in &self.entries {
            let kind = match e.f {
                ArithFn::Add { .. } => "adder".to_string(),
                ArithFn::Mul { .. } => "multiplier".to_string(),
            };
            *map.entry((kind, e.f.width())).or_default() += 1;
        }
        map.into_iter()
            .map(|((k, w), n)| (k, w, n))
            .collect()
    }

    /// Serialise the whole library.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("format", "evoapproxlib-v1".into()),
            (
                "entries",
                Json::Arr(self.entries.iter().map(Entry::to_json).collect()),
            ),
        ])
    }

    /// Deserialise.
    pub fn from_json(j: &Json) -> Result<Library, String> {
        let mut lib = Library::new();
        for e in j.req_arr("entries")? {
            lib.entries.push(Entry::from_json(e)?);
        }
        Ok(lib)
    }

    /// Save to a JSON file.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Library> {
        let text = std::fs::read_to_string(&path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Library::from_json(&j).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::baselines::{bam_multiplier, truncated_multiplier};
    use crate::circuit::cost::CostModel;
    use crate::circuit::generators::{ripple_carry_adder, wallace_multiplier};
    use crate::library::entry::Origin;

    fn mk(n: crate::circuit::netlist::Netlist, f: ArithFn) -> Entry {
        Entry::characterise(n, f, &CostModel::default(), Origin::Seed("t".into()))
    }

    #[test]
    fn insert_dedup_same_function() {
        let mut lib = Library::new();
        let f = ArithFn::Mul { w: 8 };
        assert!(lib.insert(mk(wallace_multiplier(8), f)));
        // same function, different structure (array mult is exact too)
        let added = lib.insert(mk(truncated_multiplier(8, 8), f));
        assert_eq!(lib.len(), 1, "functionally identical entries deduplicate");
        // whichever is cheaper won; `added` reflects replacement decision
        let _ = added;
    }

    #[test]
    fn census_counts() {
        let mut lib = Library::new();
        lib.insert(mk(wallace_multiplier(8), ArithFn::Mul { w: 8 }));
        lib.insert(mk(bam_multiplier(8, 0, 4), ArithFn::Mul { w: 8 }));
        lib.insert(mk(ripple_carry_adder(8), ArithFn::Add { w: 8 }));
        lib.insert(mk(ripple_carry_adder(12), ArithFn::Add { w: 12 }));
        let census = lib.census();
        assert_eq!(
            census,
            vec![
                ("adder".to_string(), 8, 1),
                ("adder".to_string(), 12, 1),
                ("multiplier".to_string(), 8, 2),
            ]
        );
    }

    #[test]
    fn save_load_round_trip() {
        let mut lib = Library::new();
        lib.insert(mk(bam_multiplier(8, 1, 3), ArithFn::Mul { w: 8 }));
        lib.insert(mk(ripple_carry_adder(6), ArithFn::Add { w: 6 }));
        let dir = std::env::temp_dir().join("evoapprox_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lib.json");
        lib.save(&path).unwrap();
        let loaded = Library::load(&path).unwrap();
        assert_eq!(loaded.len(), lib.len());
        let a = &lib.entries()[0];
        let b = loaded.get(&a.id).unwrap();
        assert_eq!(a.netlist, b.netlist);
        assert_eq!(a.metrics.mae, b.metrics.mae);
    }

    #[test]
    fn for_fn_filters() {
        let mut lib = Library::new();
        lib.insert(mk(wallace_multiplier(8), ArithFn::Mul { w: 8 }));
        lib.insert(mk(ripple_carry_adder(8), ArithFn::Add { w: 8 }));
        assert_eq!(lib.for_fn(ArithFn::Mul { w: 8 }).len(), 1);
        assert_eq!(lib.for_fn(ArithFn::Add { w: 8 }).len(), 1);
        assert_eq!(lib.for_fn(ArithFn::Add { w: 16 }).len(), 0);
    }
}
