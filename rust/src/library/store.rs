//! The library itself: a deduplicated collection of characterised entries
//! with JSON persistence and Table-I-style census reporting.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use crate::circuit::cost::CostModel;
use crate::circuit::verify::ArithFn;
use crate::util::json::Json;

use super::entry::{Entry, Origin};

/// One detailed census row: the Table-I count of a `(kind, width)` group
/// plus its circuit-cost spread.
#[derive(Debug, Clone, PartialEq)]
pub struct CensusRow {
    /// `"adder"` or `"multiplier"`.
    pub kind: String,
    /// Operand bit width.
    pub width: u32,
    /// Entries in the group.
    pub count: usize,
    /// Smallest cell area in the group [µm²].
    pub area_um2_min: f64,
    /// Largest cell area in the group [µm²].
    pub area_um2_max: f64,
    /// Shortest critical path in the group [ps].
    pub delay_ps_min: f64,
    /// Longest critical path in the group [ps].
    pub delay_ps_max: f64,
    /// Entries whose static analysis proved them exact (`wce_bound == 0`).
    pub exact_proven: u64,
    /// Largest provable worst-case-error bound in the group
    /// (`circuit::analysis`); infinite entries are clamped out by the
    /// vacuous bound, so this stays finite.
    pub wce_bound_max: f64,
}

/// A library of approximate arithmetic circuits (the EvoApproxLib analogue).
///
/// Entries are held in insertion order (`entries`), with two hash indices
/// kept in lock-step so lookups stay O(1) as the library grows:
/// `index` maps the dedup key `(function, functional-hash id)` to the
/// entry's position, and `by_fn` holds per-function position lists (in
/// insertion order) for [`Library::for_fn`]. The old linear scans made
/// every catalog merge and server library endpoint quadratic.
#[derive(Debug, Default)]
pub struct Library {
    entries: Vec<Entry>,
    index: HashMap<(ArithFn, String), usize>,
    by_fn: HashMap<ArithFn, Vec<usize>>,
}

impl Library {
    /// Empty library.
    pub fn new() -> Library {
        Library::default()
    }

    /// The built-in Table II baseline set (two truncated + eight BAM
    /// 8-bit multipliers), characterised into a ready-to-query library.
    /// This is what the analysis commands and the HTTP server fall back to
    /// when no campaign-built library file is given.
    pub fn baseline() -> Library {
        let model = CostModel::default();
        let mut lib = Library::new();
        for n in crate::circuit::baselines::table2_baselines() {
            let origin = Origin::from_baseline_name(&n.name);
            lib.insert(Entry::characterise(n, ArithFn::Mul { w: 8 }, &model, origin));
        }
        lib
    }

    /// Insert, deduplicating on `(function, functional hash)` — two circuits
    /// computing the same function keep only the *cheaper* one (by power),
    /// mirroring how the published library keeps distinct behaviours.
    /// Returns `true` if the entry was added or replaced an existing one.
    pub fn insert(&mut self, e: Entry) -> bool {
        if let Some(&i) = self.index.get(&(e.f, e.id.clone())) {
            if e.cost.power_uw < self.entries[i].cost.power_uw {
                self.entries[i] = e;
                return true;
            }
            return false;
        }
        let i = self.entries.len();
        self.index.insert((e.f, e.id.clone()), i);
        self.by_fn.entry(e.f).or_default().push(i);
        self.entries.push(e);
        true
    }

    /// All entries.
    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    /// Entries implementing `f`, in insertion order.
    pub fn for_fn(&self, f: ArithFn) -> Vec<&Entry> {
        self.by_fn
            .get(&f)
            .map(|idxs| idxs.iter().map(|&i| &self.entries[i]).collect())
            .unwrap_or_default()
    }

    /// Find by `(function, id)` — the indexed dedup key.
    pub fn get_for_fn(&self, f: ArithFn, id: &str) -> Option<&Entry> {
        self.index
            .get(&(f, id.to_string()))
            .map(|&i| &self.entries[i])
    }

    /// Find by id alone. Ids embed the function tag (`mul8u_…`), so this
    /// only has to probe the per-function indices, not scan all entries.
    pub fn get(&self, id: &str) -> Option<&Entry> {
        self.by_fn
            .keys()
            .find_map(|&f| self.get_for_fn(f, id))
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Census per `(circuit kind, bit width)` — the data of Table I.
    pub fn census(&self) -> Vec<(String, u32, usize)> {
        self.census_rows()
            .into_iter()
            .map(|r| (r.kind, r.width, r.count))
            .collect()
    }

    /// Detailed census: Table-I counts plus each group's area/delay spread
    /// from [`crate::circuit::cost::CircuitCost`] (the paper's Pareto
    /// fronts rank on more than power).
    pub fn census_rows(&self) -> Vec<CensusRow> {
        let mut map: BTreeMap<(String, u32), CensusRow> = BTreeMap::new();
        for e in &self.entries {
            let kind = match e.f {
                ArithFn::Add { .. } => "adder".to_string(),
                ArithFn::Mul { .. } => "multiplier".to_string(),
            };
            let row = map
                .entry((kind.clone(), e.f.width()))
                .or_insert_with(|| CensusRow {
                    kind,
                    width: e.f.width(),
                    count: 0,
                    area_um2_min: f64::INFINITY,
                    area_um2_max: f64::NEG_INFINITY,
                    delay_ps_min: f64::INFINITY,
                    delay_ps_max: f64::NEG_INFINITY,
                    exact_proven: 0,
                    wce_bound_max: 0.0,
                });
            row.count += 1;
            row.area_um2_min = row.area_um2_min.min(e.cost.area_um2);
            row.area_um2_max = row.area_um2_max.max(e.cost.area_um2);
            row.delay_ps_min = row.delay_ps_min.min(e.cost.delay_ps);
            row.delay_ps_max = row.delay_ps_max.max(e.cost.delay_ps);
            if e.bounds.exact_proven {
                row.exact_proven += 1;
            }
            row.wce_bound_max = row.wce_bound_max.max(e.bounds.wce_bound);
        }
        map.into_values().collect()
    }

    /// Serialise the whole library.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("format", "evoapproxlib-v1".into()),
            (
                "entries",
                Json::Arr(self.entries.iter().map(Entry::to_json).collect()),
            ),
        ])
    }

    /// Deserialise. Entries are re-inserted through [`Library::insert`] so
    /// the `(function, id)` index is rebuilt (and a hand-edited file with
    /// duplicate ids collapses to the same state `insert` would produce).
    pub fn from_json(j: &Json) -> Result<Library, String> {
        let mut lib = Library::new();
        for e in j.req_arr("entries")? {
            lib.insert(Entry::from_json(e)?);
        }
        Ok(lib)
    }

    /// Deserialise from JSON text.
    pub fn from_json_str(text: &str) -> Result<Library, String> {
        Library::from_json(&Json::parse(text)?)
    }

    /// Save to a JSON file, atomically: the serialised bytes are staged in
    /// a temp file beside the destination and renamed over it, so a crash
    /// mid-save can't truncate a multi-thousand-entry library.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        crate::util::atomic_write(path, self.to_json().to_string().as_bytes())?;
        Ok(())
    }

    /// Load from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Library> {
        let text = std::fs::read_to_string(&path)?;
        Library::from_json_str(&text).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::baselines::{bam_multiplier, truncated_multiplier};
    use crate::circuit::cost::CostModel;
    use crate::circuit::generators::{ripple_carry_adder, wallace_multiplier};
    use crate::library::entry::Origin;

    fn mk(n: crate::circuit::netlist::Netlist, f: ArithFn) -> Entry {
        Entry::characterise(n, f, &CostModel::default(), Origin::Seed("t".into()))
    }

    #[test]
    fn insert_dedup_same_function() {
        let mut lib = Library::new();
        let f = ArithFn::Mul { w: 8 };
        assert!(lib.insert(mk(wallace_multiplier(8), f)));
        // same function, different structure (array mult is exact too)
        let added = lib.insert(mk(truncated_multiplier(8, 8), f));
        assert_eq!(lib.len(), 1, "functionally identical entries deduplicate");
        // whichever is cheaper won; `added` reflects replacement decision
        let _ = added;
    }

    #[test]
    fn census_counts() {
        let mut lib = Library::new();
        lib.insert(mk(wallace_multiplier(8), ArithFn::Mul { w: 8 }));
        lib.insert(mk(bam_multiplier(8, 0, 4), ArithFn::Mul { w: 8 }));
        lib.insert(mk(ripple_carry_adder(8), ArithFn::Add { w: 8 }));
        lib.insert(mk(ripple_carry_adder(12), ArithFn::Add { w: 12 }));
        let census = lib.census();
        assert_eq!(
            census,
            vec![
                ("adder".to_string(), 8, 1),
                ("adder".to_string(), 12, 1),
                ("multiplier".to_string(), 8, 2),
            ]
        );
    }

    #[test]
    fn census_rows_carry_cost_spread() {
        let mut lib = Library::new();
        lib.insert(mk(wallace_multiplier(8), ArithFn::Mul { w: 8 }));
        lib.insert(mk(bam_multiplier(8, 0, 4), ArithFn::Mul { w: 8 }));
        lib.insert(mk(truncated_multiplier(8, 6), ArithFn::Mul { w: 8 }));
        let rows = lib.census_rows();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!((r.kind.as_str(), r.width, r.count), ("multiplier", 8, 3));
        // the approximations are strictly smaller than the exact wallace
        assert!(r.area_um2_min < r.area_um2_max, "{r:?}");
        assert!(r.area_um2_min > 0.0 && r.delay_ps_min > 0.0);
        assert!(r.delay_ps_min <= r.delay_ps_max);
        // static-analysis aggregates: the exact wallace is proven exact,
        // and the lossy entries give the group a nonzero bound ceiling
        assert_eq!(r.exact_proven, 1);
        assert!(r.wce_bound_max > 0.0 && r.wce_bound_max.is_finite(), "{r:?}");
        // the tuple census stays the old shape
        assert_eq!(
            lib.census(),
            vec![("multiplier".to_string(), 8, 3)]
        );
    }

    #[test]
    fn save_load_round_trip() {
        let mut lib = Library::new();
        lib.insert(mk(bam_multiplier(8, 1, 3), ArithFn::Mul { w: 8 }));
        lib.insert(mk(ripple_carry_adder(6), ArithFn::Add { w: 6 }));
        let dir = std::env::temp_dir().join("evoapprox_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lib.json");
        lib.save(&path).unwrap();
        let loaded = Library::load(&path).unwrap();
        assert_eq!(loaded.len(), lib.len());
        let a = &lib.entries()[0];
        let b = loaded.get(&a.id).unwrap();
        assert_eq!(a.netlist, b.netlist);
        assert_eq!(a.metrics.mae, b.metrics.mae);
        assert_eq!(a.bounds, b.bounds, "static bounds round-trip via JSON");
    }

    /// `save` must replace a pre-existing destination atomically: after the
    /// save the file holds exactly the new library (the rename is all or
    /// nothing) and no temp staging file survives in the directory.
    #[test]
    fn save_replaces_existing_destination_atomically() {
        let dir = std::env::temp_dir().join("evoapprox_test_store_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lib.json");
        // pre-existing destination: garbage much longer than the real save
        std::fs::write(&path, "x".repeat(1 << 20)).unwrap();
        let mut lib = Library::new();
        lib.insert(mk(bam_multiplier(8, 0, 4), ArithFn::Mul { w: 8 }));
        lib.save(&path).unwrap();
        let loaded = Library::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded.entries()[0].id, lib.entries()[0].id);
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn index_tracks_inserts_and_replacements() {
        let mut lib = Library::new();
        let f = ArithFn::Mul { w: 8 };
        let mut a = mk(bam_multiplier(8, 0, 4), f);
        a.cost.power_uw = 50.0;
        assert!(lib.insert(a.clone()));
        // indexed lookups agree with the entry list
        assert_eq!(lib.get_for_fn(f, &a.id).unwrap().cost.power_uw, 50.0);
        assert_eq!(lib.get(&a.id).unwrap().id, a.id);
        // a cheaper functional duplicate replaces in place…
        let mut b = a.clone();
        b.cost.power_uw = 25.0;
        assert!(lib.insert(b));
        assert_eq!(lib.len(), 1);
        assert_eq!(lib.get_for_fn(f, &a.id).unwrap().cost.power_uw, 25.0);
        // …and a dearer one is rejected without disturbing the index
        let mut c = a.clone();
        c.cost.power_uw = 99.0;
        assert!(!lib.insert(c));
        assert_eq!(lib.get(&a.id).unwrap().cost.power_uw, 25.0);
        assert!(lib.get_for_fn(ArithFn::Add { w: 8 }, &a.id).is_none());
        assert!(lib.get("mul8u_FFFF_missing").is_none());
    }

    #[test]
    fn baseline_library_is_queryable() {
        let lib = Library::baseline();
        assert!(!lib.is_empty());
        let mults = lib.for_fn(ArithFn::Mul { w: 8 });
        assert_eq!(mults.len(), lib.len());
        for e in mults {
            assert_eq!(lib.get(&e.id).unwrap().id, e.id);
        }
    }

    #[test]
    fn for_fn_filters() {
        let mut lib = Library::new();
        lib.insert(mk(wallace_multiplier(8), ArithFn::Mul { w: 8 }));
        lib.insert(mk(ripple_carry_adder(8), ArithFn::Add { w: 8 }));
        assert_eq!(lib.for_fn(ArithFn::Mul { w: 8 }).len(), 1);
        assert_eq!(lib.for_fn(ArithFn::Add { w: 8 }).len(), 1);
        assert_eq!(lib.for_fn(ArithFn::Add { w: 16 }).len(), 0);
    }
}
