//! The paper's circuit-selection procedure (§III/§IV):
//!
//! 1. for each of the five error metrics (ER, MAE, WCE, MSE, MRE), extract
//!    the Pareto front of (power, metric);
//! 2. take 10 circuits evenly distributed along the power axis;
//! 3. union the five subsets and drop functional duplicates — the paper
//!    lands on 35 unique approximate multipliers this way.

use crate::cgp::metrics::Metric;
use crate::cgp::pareto::non_dominated_indices;
use crate::circuit::verify::ArithFn;

use super::entry::Entry;
use super::store::Library;

/// Indices (into `entries`) of the (power, metric)-Pareto-optimal entries.
pub fn pareto_indices(entries: &[&Entry], metric: Metric) -> Vec<usize> {
    let objs: Vec<Vec<f64>> = entries
        .iter()
        .map(|e| vec![e.cost.power_uw, metric.of(&e.metrics)])
        .collect();
    non_dominated_indices(&objs)
}

/// Pick (up to) `k` front members evenly spaced along the power axis:
/// for each of `k` equidistant target powers between the front's min and
/// max, take the nearest not-yet-chosen member.
pub fn evenly_by_power<'e>(front: &[&'e Entry], k: usize) -> Vec<&'e Entry> {
    if front.is_empty() || k == 0 {
        return Vec::new();
    }
    let mut sorted: Vec<&Entry> = front.to_vec();
    sorted.sort_by(|a, b| a.cost.power_uw.total_cmp(&b.cost.power_uw));
    if sorted.len() <= k {
        return sorted;
    }
    let lo = sorted.first().unwrap().cost.power_uw;
    let hi = sorted.last().unwrap().cost.power_uw;
    let mut taken = vec![false; sorted.len()];
    let mut out = Vec::with_capacity(k);
    for i in 0..k {
        let target = lo + (hi - lo) * i as f64 / (k - 1) as f64;
        let mut best: Option<(f64, usize)> = None;
        for (j, e) in sorted.iter().enumerate() {
            if taken[j] {
                continue;
            }
            let d = (e.cost.power_uw - target).abs();
            if best.map_or(true, |(bd, _)| d < bd) {
                best = Some((d, j));
            }
        }
        if let Some((_, j)) = best {
            taken[j] = true;
            out.push(sorted[j]);
        }
    }
    out.sort_by(|a, b| a.cost.power_uw.total_cmp(&b.cost.power_uw));
    out
}

/// The full §IV selection: per-metric Pareto subsets of `k` → union →
/// functional dedup (by id). Returns entries sorted by descending power
/// (Table II row order).
pub fn select_diverse<'l>(
    lib: &'l Library,
    f: ArithFn,
    metrics: &[Metric],
    k: usize,
) -> Vec<&'l Entry> {
    let all = lib.for_fn(f);
    let mut chosen: Vec<&Entry> = Vec::new();
    for &m in metrics {
        let front_idx = pareto_indices(&all, m);
        let front: Vec<&Entry> = front_idx.iter().map(|&i| all[i]).collect();
        for e in evenly_by_power(&front, k) {
            if !chosen.iter().any(|c| c.id == e.id) {
                chosen.push(e);
            }
        }
    }
    chosen.sort_by(|a, b| b.cost.power_uw.total_cmp(&a.cost.power_uw));
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgp::metrics::SELECTION_METRICS;
    use crate::circuit::baselines::{bam_multiplier, truncated_multiplier};
    use crate::circuit::cost::CostModel;
    use crate::circuit::generators::wallace_multiplier;
    use crate::library::entry::Origin;

    fn test_library() -> Library {
        let model = CostModel::default();
        let f = ArithFn::Mul { w: 8 };
        let mut lib = Library::new();
        lib.insert(Entry::characterise(
            wallace_multiplier(8),
            f,
            &model,
            Origin::Seed("wallace".into()),
        ));
        for keep in [5, 6, 7] {
            lib.insert(Entry::characterise(
                truncated_multiplier(8, keep),
                f,
                &model,
                Origin::Truncated { keep },
            ));
        }
        for (h, v) in [(0, 2), (0, 4), (1, 3), (0, 6), (1, 6), (0, 7), (2, 7), (2, 8)] {
            lib.insert(Entry::characterise(
                bam_multiplier(8, h, v),
                f,
                &model,
                Origin::Bam { h, v },
            ));
        }
        lib
    }

    #[test]
    fn pareto_front_excludes_dominated() {
        let lib = test_library();
        let all = lib.for_fn(ArithFn::Mul { w: 8 });
        let front = pareto_indices(&all, Metric::Mae);
        assert!(!front.is_empty());
        assert!(front.len() < all.len(), "some entries must be dominated");
        // the exact multiplier (mae = 0) is always on the front
        let has_exact = front.iter().any(|&i| all[i].metrics.mae == 0.0);
        assert!(has_exact);
    }

    #[test]
    fn evenly_by_power_spacing() {
        let lib = test_library();
        let all = lib.for_fn(ArithFn::Mul { w: 8 });
        let front_idx = pareto_indices(&all, Metric::Mae);
        let front: Vec<&Entry> = front_idx.iter().map(|&i| all[i]).collect();
        let picked = evenly_by_power(&front, 4);
        assert!(picked.len() <= 4);
        assert!(picked.len() >= 2.min(front.len()));
        // sorted by power ascending, no duplicates
        for w in picked.windows(2) {
            assert!(w[0].cost.power_uw <= w[1].cost.power_uw);
            assert_ne!(w[0].id, w[1].id);
        }
        // extremes of the front are included
        let mut sorted = front.clone();
        sorted.sort_by(|a, b| a.cost.power_uw.total_cmp(&b.cost.power_uw));
        assert_eq!(picked.first().unwrap().id, sorted.first().unwrap().id);
        assert_eq!(picked.last().unwrap().id, sorted.last().unwrap().id);
    }

    #[test]
    fn select_diverse_dedups_across_metrics() {
        let lib = test_library();
        let sel = select_diverse(&lib, ArithFn::Mul { w: 8 }, &SELECTION_METRICS, 10);
        assert!(!sel.is_empty());
        for i in 0..sel.len() {
            for j in (i + 1)..sel.len() {
                assert_ne!(sel[i].id, sel[j].id);
            }
        }
        // descending power order (Table II)
        for w in sel.windows(2) {
            assert!(w[0].cost.power_uw >= w[1].cost.power_uw);
        }
    }

    /// A NaN power characterisation (e.g. a corrupt library file) must not
    /// panic the selection path — the server exposes it on a GET endpoint.
    /// `total_cmp` orders NaN after every real number instead of unwrapping.
    #[test]
    fn nan_power_does_not_panic_selection() {
        let mut lib = test_library();
        let model = CostModel::default();
        let f = ArithFn::Mul { w: 8 };
        let mut poison = Entry::characterise(
            bam_multiplier(8, 3, 9),
            f,
            &model,
            Origin::Bam { h: 3, v: 9 },
        );
        poison.cost.power_uw = f64::NAN;
        lib.insert(poison);
        let all = lib.for_fn(f);
        // all three sort sites: evenly_by_power (two sorts) + select_diverse
        let _ = evenly_by_power(&all, 4);
        let sel = select_diverse(&lib, f, &SELECTION_METRICS, 10);
        assert!(!sel.is_empty());
        // the finite-powered prefix still comes out in descending order
        for w in sel.windows(2) {
            if w[0].cost.power_uw.is_finite() && w[1].cost.power_uw.is_finite() {
                assert!(w[0].cost.power_uw >= w[1].cost.power_uw);
            }
        }
    }

    #[test]
    fn small_front_returned_whole() {
        let lib = test_library();
        let all = lib.for_fn(ArithFn::Mul { w: 8 });
        let two: Vec<&Entry> = all.into_iter().take(2).collect();
        assert_eq!(evenly_by_power(&two, 10).len(), 2);
        assert!(evenly_by_power(&[], 10).is_empty());
    }
}
