"""Synthetic 10-class image dataset — Python mirror of ``rust/src/data/``.

The paper trains on CIFAR-10; this reproduction substitutes a seeded
synthetic texture-classification task (DESIGN.md §4). The generator below
implements the same algorithm as ``rust/src/data/dataset.rs`` (same
SplitMix64 stream, same class parameterisation); the canonical evaluation
split is exported into ``artifacts/`` by ``aot.py`` so the Rust analysis
side consumes exactly these arrays.
"""

from __future__ import annotations

import math

import numpy as np

N_CLASSES = 10
IMAGE_SIZE = 16
N_CHANNELS = 3
IMAGE_LEN = IMAGE_SIZE * IMAGE_SIZE * N_CHANNELS

_MASK = (1 << 64) - 1


def _splitmix_stream(seed: int, n: int) -> np.ndarray:
    """First ``n`` outputs of SplitMix64 for ``seed`` (uint64 array)."""
    out = np.empty(n, dtype=np.uint64)
    state = seed & _MASK
    for i in range(n):
        state = (state + 0x9E3779B97F4A7C15) & _MASK
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
        out[i] = z ^ (z >> 31)
    return out


def _to_f64(u: np.ndarray) -> np.ndarray:
    """Map uint64 draws to [0, 1) exactly like ``SplitMix64::next_f64``."""
    return (u >> np.uint64(11)).astype(np.float64) * (1.0 / float(1 << 53))


def _class_params(class_id: int) -> tuple[float, float, float, float]:
    c = float(class_id)
    angle = c * math.pi / N_CLASSES
    freq = 0.55 + 0.09 * c
    kx = freq * math.cos(angle)
    ky = freq * math.sin(angle)
    radial = 0.35 if class_id % 2 == 0 else 0.0
    return kx, ky, radial, c * 0.7


def gen_image(seed: int, index: int, class_id: int, noise: float) -> np.ndarray:
    """One image, identical to the Rust ``gen_image`` draw-for-draw."""
    s = (
        seed
        ^ ((index * 0x9E3779B97F4A7C15) & _MASK)
        ^ ((class_id & 0xFF) << 56)
    ) & _MASK
    # draws: dx, dy, contrast, then 4 per pixel-channel
    n_draws = 3 + 4 * IMAGE_LEN
    u = _to_f64(_splitmix_stream(s, n_draws))
    dx, dy, cdraw = u[0] * 3.0, u[1] * 3.0, u[2]
    contrast = 0.8 + 0.4 * cdraw
    kx, ky, radial_w, phase0 = _class_params(class_id)

    y, x = np.meshgrid(
        np.arange(IMAGE_SIZE, dtype=np.float64),
        np.arange(IMAGE_SIZE, dtype=np.float64),
        indexing="ij",
    )
    centre = IMAGE_SIZE / 2.0
    r = np.sqrt((x - centre) ** 2 + (y - centre) ** 2)
    img = np.empty((IMAGE_SIZE, IMAGE_SIZE, N_CHANNELS), dtype=np.float64)
    # noise draws are consumed in (y, x, ch) order, 4 per value
    nz = u[3:].reshape(IMAGE_SIZE, IMAGE_SIZE, N_CHANNELS, 4)
    gauss = nz.sum(axis=-1) - 2.0  # Irwin–Hall(4), mirrored from Rust
    for ch in range(N_CHANNELS):
        phase = phase0 + ch * 2.1
        wave = np.sin(kx * (x + dx) + ky * (y + dy) + phase)
        ring = np.sin(0.9 * r + phase)
        v = 0.5 + contrast * (0.35 * wave + radial_w * 0.35 * ring)
        img[..., ch] = v + noise * gauss[..., ch] * 1.732
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def make_dataset(n: int, seed: int = 0xC1FA2020, noise: float = 0.10):
    """``n`` images (round-robin balanced classes) + labels."""
    images = np.empty((n, IMAGE_SIZE, IMAGE_SIZE, N_CHANNELS), dtype=np.float32)
    labels = np.empty(n, dtype=np.uint8)
    for k in range(n):
        c = k % N_CLASSES
        images[k] = gen_image(seed, k, c, noise)
        labels[k] = c
    return images, labels


# Canonical split seeds: train/calibration/test never overlap because the
# per-sample stream is keyed on (seed, index) and the seeds differ.
TRAIN_SEED = 0xC1FA2020
CALIB_SEED = 0xCA11B000
TEST_SEED = 0x7E57E75


# The canonical splits use a harder noise level than the default so the
# baseline accuracy sits below the ceiling and approximate-multiplier
# degradation is *graded* (Table II's interesting middle rows), not binary.
CANONICAL_NOISE = 0.22


def canonical_splits(n_train: int, n_calib: int, n_test: int):
    """The splits used by train.py / aot.py (and exported for Rust)."""
    return (
        make_dataset(n_train, TRAIN_SEED, CANONICAL_NOISE),
        make_dataset(n_calib, CALIB_SEED, CANONICAL_NOISE),
        make_dataset(n_test, TEST_SEED, CANONICAL_NOISE),
    )
