"""Float training of the ResNet family on the synthetic dataset.

Build-time only (invoked by ``aot.py`` under ``make artifacts``). Hand-rolled
Adam + cosine schedule (the environment ships no optax); single-core CPU
budgets are deliberate: the networks are narrow (width 8) and images small
(16x16), see DESIGN.md §4 scaling notes.
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return dict(m=zeros, v=jax.tree_util.tree_map(jnp.zeros_like, params), t=0)


def adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new_params, dict(m=m, v=v, t=t)


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def train_model(depth: int, width: int, train_data, steps: int = 1200,
                batch: int = 64, base_lr: float = 3e-3, seed: int = 0,
                log_every: int = 200, target_acc: float = 0.995):
    """Train one ResNet; returns (params, state, spec, history)."""
    spec = M.resnet_spec(depth, width)
    images, labels = train_data
    n = images.shape[0]
    rng = jax.random.PRNGKey(seed + depth)
    params, state = M.init_params(rng, spec)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, state, opt, x, y, lr):
        def loss_fn(p):
            logits, new_state, _ = M.forward_float(p, state, spec, x, True)
            return cross_entropy(logits, y), (logits, new_state)
        (loss, (logits, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt = adam_update(params, grads, opt, lr)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return params, new_state, opt, loss, acc

    perm_rng = np.random.default_rng(seed + depth)
    history = []
    t0 = time.time()
    smooth_acc = 0.0
    for step in range(steps):
        idx = perm_rng.integers(0, n, size=batch)
        x = jnp.asarray(images[idx])
        y = jnp.asarray(labels[idx].astype(np.int32))
        lr = base_lr * 0.5 * (1 + np.cos(np.pi * step / steps))
        params, state, opt, loss, acc = step_fn(params, state, opt, x, y, lr)
        smooth_acc = 0.95 * smooth_acc + 0.05 * float(acc)
        if step % log_every == 0 or step == steps - 1:
            history.append(dict(step=step, loss=float(loss), acc=float(acc),
                                wall=time.time() - t0))
            print(f"  resnet{depth} step {step:5d} loss {float(loss):.4f} "
                  f"acc {float(acc):.3f} ({time.time()-t0:.1f}s)", flush=True)
        if smooth_acc > target_acc and step > steps // 4:
            history.append(dict(step=step, loss=float(loss), acc=float(acc),
                                wall=time.time() - t0))
            print(f"  resnet{depth} early stop at {step} "
                  f"(smoothed acc {smooth_acc:.3f})", flush=True)
            break
    return params, state, spec, history


def evaluate_float(params, state, spec, data, batch: int = 128):
    """Eval-mode accuracy of the float model."""
    images, labels = data
    fwd = jax.jit(lambda x: M.forward_float(params, state, spec, x, False)[0])
    correct = 0
    for i in range(0, images.shape[0], batch):
        logits = fwd(jnp.asarray(images[i:i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1)
                               == jnp.asarray(labels[i:i + batch].astype(np.int32))))
    return correct / images.shape[0]


def calibration_activations(params, state, spec, calib_data):
    """Per-conv-layer input activations of the float model (eval mode) on
    the calibration split — drives post-training quantisation ranges."""
    images, _ = calib_data
    fwd = jax.jit(lambda x: M.forward_float(params, state, spec, x, False)[2])
    acts = fwd(jnp.asarray(images))
    return [np.asarray(a) for a in acts]
