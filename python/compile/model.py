"""L2 — the ResNet family (He et al., CIFAR variant: depth = 6n+2) in JAX.

Two forward paths share one architecture description:

* ``forward_float`` — float32 training/eval path (conv + batch-norm + ReLU,
  option-A parameter-free shortcuts, global average pool, dense head). Used
  by ``train.py``; never shipped.
* ``forward_quant`` — the AOT-exported inference path: batch-norm is folded
  into the convolutions, every convolution runs on uint8 codes through the
  LUT-multiplier kernel (L1), with per-layer LUTs passed as a runtime input
  ``luts[L, 65536]``. Swapping an approximate multiplier therefore needs NO
  recompilation — the Rust coordinator just feeds a different LUT row.

The paper's ResNet-8 has 7 conv layers (stem + 3 stages x 1 block x 2
convs); Fig. 4 labels them (S, R, C). We track those labels per layer and
export them in the manifest together with per-layer multiplication counts
(the basis of the accelerator power model, `rust/src/accel`).

Quantisation follows TFApprox: asymmetric uint8 fake-quant at every conv
boundary; accumulators are corrected with exact-multiplier zero-point
algebra (exact when the LUT is the exact product table — pinned by tests).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref as kref
from .kernels.approx_conv import lut_matmul

N_CLASSES = 10
STAGE_WIDTH_MULTS = (1, 2, 4)
BN_EPS = 1e-5
BN_MOMENTUM = 0.9

SUPPORTED_DEPTHS = (8, 14, 20, 26, 32, 38, 44, 50)


# --------------------------------------------------------------------------
# architecture description
# --------------------------------------------------------------------------

def resnet_spec(depth: int, width: int = 8):
    """Layer plan for a 6n+2 ResNet.

    Returns a dict with ``conv_layers``: execution-ordered conv descriptors
    ``{cin, cout, stride, stage, block, conv}`` (stage 0 = stem), and the
    block structure used by the forward passes.
    """
    assert (depth - 2) % 6 == 0, f"depth {depth} is not 6n+2"
    n = (depth - 2) // 6
    convs = [dict(cin=3, cout=width, stride=1, stage=0, block=1, conv=1)]
    blocks = []
    cin = width
    for stage in range(3):
        cout = width * STAGE_WIDTH_MULTS[stage]
        for block in range(n):
            stride = 2 if (stage > 0 and block == 0) else 1
            convs.append(
                dict(cin=cin, cout=cout, stride=stride,
                     stage=stage + 1, block=block + 1, conv=1)
            )
            convs.append(
                dict(cin=cout, cout=cout, stride=1,
                     stage=stage + 1, block=block + 1, conv=2)
            )
            blocks.append(dict(stage=stage + 1, block=block + 1,
                               stride=stride, cin=cin, cout=cout))
            cin = cout
    return dict(depth=depth, n=n, width=width, conv_layers=convs,
                blocks=blocks, feat=cin)


def layer_mult_counts(spec, image_size: int = 16):
    """Multiplications per image for every conv layer (Fig. 4's percentages
    and the accelerator power model both derive from these counts)."""
    counts = []
    size = image_size
    for i, c in enumerate(spec["conv_layers"]):
        if i > 0 and c["stride"] == 2:
            size //= 2
        counts.append(size * size * 3 * 3 * c["cin"] * c["cout"])
    return counts


# --------------------------------------------------------------------------
# float path (training)
# --------------------------------------------------------------------------

def init_params(rng, spec):
    """He-initialised parameters + batch-norm state."""
    params, state = [], []
    keys = jax.random.split(rng, len(spec["conv_layers"]) + 1)
    for key, c in zip(keys[:-1], spec["conv_layers"]):
        fan_in = 3 * 3 * c["cin"]
        w = jax.random.normal(key, (3, 3, c["cin"], c["cout"]),
                              jnp.float32) * math.sqrt(2.0 / fan_in)
        params.append(dict(w=w,
                           gamma=jnp.ones(c["cout"], jnp.float32),
                           beta=jnp.zeros(c["cout"], jnp.float32)))
        state.append(dict(mean=jnp.zeros(c["cout"], jnp.float32),
                          var=jnp.ones(c["cout"], jnp.float32)))
    feat = spec["feat"]
    params.append(dict(
        w=jax.random.normal(keys[-1], (feat, N_CLASSES), jnp.float32)
        / math.sqrt(feat),
        b=jnp.zeros(N_CLASSES, jnp.float32),
    ))
    return params, state


def _conv_f(x, w, stride):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(x, p, s, train):
    if train:
        mean = x.mean(axis=(0, 1, 2))
        var = x.var(axis=(0, 1, 2))
        new_s = dict(
            mean=BN_MOMENTUM * s["mean"] + (1 - BN_MOMENTUM) * mean,
            var=BN_MOMENTUM * s["var"] + (1 - BN_MOMENTUM) * var,
        )
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (x - mean) / jnp.sqrt(var + BN_EPS) * p["gamma"] + p["beta"]
    return y, new_s


def _shortcut_a(x, stride, cout):
    """Option-A parameter-free shortcut: subsample + zero-pad channels."""
    if stride > 1:
        x = x[:, ::stride, ::stride, :]
    cin = x.shape[-1]
    if cout > cin:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cout - cin)))
    return x


def forward_float(params, state, spec, x, train: bool):
    """Float forward; returns (logits, new_state, activations) where
    ``activations[i]`` is the input of conv layer ``i`` (calibration)."""
    acts = []
    new_state = list(state)
    li = 0
    acts.append(x)
    h = _conv_f(x, params[0]["w"], 1)
    h, new_state[0] = _bn(h, params[0], state[0], train)
    h = jax.nn.relu(h)
    li = 1
    for blk in spec["blocks"]:
        inp = h
        acts.append(h)
        h = _conv_f(h, params[li]["w"], blk["stride"])
        h, new_state[li] = _bn(h, params[li], state[li], train)
        h = jax.nn.relu(h)
        li += 1
        acts.append(h)
        h = _conv_f(h, params[li]["w"], 1)
        h, new_state[li] = _bn(h, params[li], state[li], train)
        li += 1
        h = jax.nn.relu(h + _shortcut_a(inp, blk["stride"], blk["cout"]))
    gap = h.mean(axis=(1, 2))
    logits = gap @ params[-1]["w"] + params[-1]["b"]
    return logits, new_state, acts


# --------------------------------------------------------------------------
# BN folding + post-training quantisation
# --------------------------------------------------------------------------

def fold_bn(params, state, spec):
    """Fold batch norm into conv weight + bias:
    ``w' = w * g/sqrt(v+eps)``, ``b' = beta - mean * g/sqrt(v+eps)``."""
    folded = []
    for p, s, _c in zip(params[:-1], state, spec["conv_layers"]):
        scale = p["gamma"] / jnp.sqrt(s["var"] + BN_EPS)
        folded.append(dict(w=p["w"] * scale[None, None, None, :],
                           b=p["beta"] - s["mean"] * scale))
    return folded, dict(w=params[-1]["w"], b=params[-1]["b"])


def quant_range(x, qmax: int = 255):
    """Asymmetric uint8 (scale, zero_point) covering [min(x,0), max(x,0)]."""
    lo = float(np.minimum(np.min(x), 0.0))
    hi = float(np.maximum(np.max(x), 0.0))
    if hi - lo < 1e-12:
        return 1.0, 0
    scale = (hi - lo) / qmax
    zp = int(round(-lo / scale))
    return scale, int(np.clip(zp, 0, qmax))


def quantize_codes(x, scale, zp, qmax: int = 255):
    return np.clip(np.round(np.asarray(x) / scale) + zp, 0, qmax).astype(np.int32)


def quantize_model(folded, dense, spec, calib_acts):
    """Post-training quantisation: per-layer weight codes + activation
    (scale, zp) from float-model calibration activations."""
    qlayers = []
    for p, act, c in zip(folded, calib_acts, spec["conv_layers"]):
        s_w, z_w = quant_range(np.asarray(p["w"]))
        w_q = quantize_codes(p["w"], s_w, z_w)
        s_a, z_a = quant_range(np.asarray(act))
        qlayers.append(dict(
            w_q=w_q, s_w=s_w, z_w=z_w, s_a=s_a, z_a=z_a,
            b=np.asarray(p["b"], np.float32), stride=c["stride"],
        ))
    return dict(layers=qlayers,
                dense_w=np.asarray(dense["w"], np.float32),
                dense_b=np.asarray(dense["b"], np.float32))


# --------------------------------------------------------------------------
# quantised LUT forward (the AOT-exported graph)
# --------------------------------------------------------------------------

def _approx_conv_q(h_float, q, lut, use_pallas):
    """Fake-quant boundary + LUT conv + dequant for one layer.

    ``h_float``: float input activations; quantised with the layer's
    calibrated (s_a, z_a); weights are pre-quantised codes.
    """
    s_a, z_a, s_w, z_w = q["s_a"], q["z_a"], q["s_w"], q["z_w"]
    codes = jnp.clip(jnp.round(h_float / s_a) + z_a, 0, 255).astype(jnp.int32)
    kh, kw, cin, cout = q["w_q"].shape
    stride = q["stride"]
    # im2col on zero-shifted codes so SAME padding contributes z_a codes
    patches = kref.im2col((codes - z_a).astype(jnp.float32), kh, kw, stride)
    patches = (patches.astype(jnp.int32) + z_a)
    b, ho, wo, k = patches.shape
    p2 = patches.reshape(b * ho * wo, k)
    w2 = jnp.asarray(q["w_q"]).reshape(k, cout)
    s = lut_matmul(p2, w2, lut, use_pallas=use_pallas)
    a_sum = p2.sum(axis=1, dtype=jnp.int32)[:, None]
    w_sum = w2.sum(axis=0, dtype=jnp.int32)[None, :]
    y = kref.dequantize_acc(s, a_sum, w_sum, k, s_a, z_a, s_w, z_w)
    y = y.reshape(b, ho, wo, cout) + jnp.asarray(q["b"])
    return y


def forward_quant(qmodel, spec, x, luts, use_pallas: bool = False):
    """Quantised inference: ``luts[i]`` is conv layer ``i``'s product table.

    Args:
      qmodel: output of :func:`quantize_model` (weights become constants in
        the lowered graph).
      x: ``[B, H, W, 3]`` float32 images in [0, 1].
      luts: ``[L, 65536]`` int32 — one LUT row per conv layer.
      use_pallas: route the matmuls through the Pallas kernel (L1) instead
        of the pure-jnp oracle formulation (same semantics).

    Returns:
      ``[B, 10]`` float32 logits.
    """
    qs = qmodel["layers"]
    h = _approx_conv_q(x, qs[0], luts[0], use_pallas)
    h = jax.nn.relu(h)
    li = 1
    for blk in spec["blocks"]:
        inp = h
        h = _approx_conv_q(h, qs[li], luts[li], use_pallas)
        h = jax.nn.relu(h)
        li += 1
        h = _approx_conv_q(h, qs[li], luts[li], use_pallas)
        li += 1
        h = jax.nn.relu(h + _shortcut_a(inp, blk["stride"], blk["cout"]))
    gap = h.mean(axis=(1, 2))
    return gap @ jnp.asarray(qmodel["dense_w"]) + jnp.asarray(qmodel["dense_b"])


def make_inference_fn(qmodel, spec, use_pallas: bool = False):
    """The function that gets AOT-lowered: (images, luts) -> (logits,)."""
    def fn(images, luts):
        return (forward_quant(qmodel, spec, images, luts, use_pallas),)
    return fn


# --------------------------------------------------------------------------
# helpers shared with train/aot
# --------------------------------------------------------------------------

def accuracy(logits, labels):
    return float(jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32)))


def exact_luts(n_layers: int):
    """[L, 65536] exact product tables (the golden 8-bit multiplier)."""
    return jnp.broadcast_to(kref.exact_lut()[None, :], (n_layers, 256 * 256))
