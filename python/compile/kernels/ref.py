"""Pure-jnp oracle for the LUT-multiplier kernels (L1 correctness anchor).

Semantics (TFApprox-equivalent): operands are uint8 *codes*; every scalar
product ``a*w`` inside a matmul/convolution is replaced by ``lut[a*256+w]``,
where ``lut`` is the exhaustive 256x256 product table of an (approximate)
8-bit multiplier. With the exact product table this reduces to ordinary
integer arithmetic, which is what the tests pin down.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

LUT_SIZE = 256 * 256


def lut_matmul_ref(p, w, lut):
    """``S[m, n] = sum_k lut[p[m, k] * 256 + w[k, n]]``.

    Args:
      p: ``[M, K]`` int32 codes in [0, 256).
      w: ``[K, N]`` int32 codes in [0, 256).
      lut: ``[65536]`` int32 product table.

    Returns:
      ``[M, N]`` int32 accumulator.
    """
    idx = p[:, :, None] * 256 + w[None, :, :]  # [M, K, N]
    return jnp.take(lut, idx, axis=0).sum(axis=1, dtype=jnp.int32)


def exact_lut():
    """The exact 8-bit product table (the paper's golden multiplier)."""
    a = jnp.arange(256, dtype=jnp.int32)
    return (a[:, None] * a[None, :]).reshape(-1)


def im2col(x, kh: int, kw: int, stride: int):
    """Extract conv patches: ``[B, H, W, C] -> [B, Ho, Wo, kh*kw*C]``.

    SAME padding with zeros; zero maps to quantisation code ``z_a`` at the
    caller (padding is applied on *codes*, so callers pad with ``z_a``).
    """
    b, h, w_, c = x.shape
    patches = lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches returns channel-major patch features
    # [B, Ho, Wo, C*kh*kw]; reorder to (kh, kw, C) patch layout to match
    # weight layout [kh, kw, C, O].
    bo, ho, wo, _ = patches.shape
    patches = patches.reshape(bo, ho, wo, c, kh * kw)
    patches = jnp.moveaxis(patches, 3, 4).reshape(bo, ho, wo, kh * kw * c)
    return patches


def approx_conv2d_ref(x_codes, w_codes, lut, stride: int, z_a: int):
    """Approximate 2-D convolution on uint8 codes via the LUT.

    Args:
      x_codes: ``[B, H, W, C]`` int32 activation codes.
      w_codes: ``[kh, kw, C, O]`` int32 weight codes.
      lut: ``[65536]`` int32 product table.
      stride: spatial stride (SAME padding).
      z_a: activation zero-point — used as the padding code.

    Returns:
      ``[B, Ho, Wo, O]`` int32 accumulator ``S`` plus the per-position sum of
      activation codes (needed for zero-point correction), as a tuple.
    """
    kh, kw, c, o = w_codes.shape
    # pad with z_a so padded positions behave like dequantised zeros
    x_shift = x_codes - z_a
    patches = im2col(x_shift, kh, kw, stride)  # zero-padded shifted codes
    patches = (patches + z_a).astype(jnp.int32)  # restore codes; pads = z_a
    b, ho, wo, k = patches.shape
    p2 = patches.reshape(b * ho * wo, k)
    w2 = w_codes.reshape(k, o).astype(jnp.int32)
    s = lut_matmul_ref(p2, w2, lut).reshape(b, ho, wo, o)
    a_sum = p2.sum(axis=1, dtype=jnp.int32).reshape(b, ho, wo, 1)
    return s, a_sum


def dequantize_acc(s, a_sum, w_sum, k, s_a, z_a, s_w, z_w):
    """Zero-point-corrected dequantisation of a LUT-matmul accumulator.

    ``y = s_a * s_w * (S - z_w * sum_a - z_a * sum_w + K * z_a * z_w)``
    — exact when the LUT is the exact product table.
    """
    corr = (
        s.astype(jnp.float32)
        - jnp.float32(z_w) * a_sum.astype(jnp.float32)
        - jnp.float32(z_a) * w_sum.astype(jnp.float32)
        + jnp.float32(k) * jnp.float32(z_a) * jnp.float32(z_w)
    )
    return jnp.float32(s_a) * jnp.float32(s_w) * corr
