"""L1 — Pallas LUT-gather matmul kernel (the compute hot-spot).

TPU mapping of TFApprox's CUDA kernel (DESIGN.md §3 Hardware-Adaptation):

* the 256x256 i32 product LUT (256 KiB) gets its own ``BlockSpec`` with a
  constant index map — it is staged HBM→VMEM once and reused by every grid
  step (CUDA staged it per threadblock in shared memory);
* an arbitrary LUT breaks MXU bilinearity, so the kernel targets the VPU
  with a vectorised gather; tiles are sized for VPU lanes (N multiples of
  128 on hardware — smaller here so tests stay fast under interpret mode);
* the grid is (M-tiles, N-tiles, K-tiles) with K innermost and an i32
  accumulator block revisited across K steps, so partial sums never touch
  HBM (CUDA used a threadblock-resident accumulator).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; on-TPU numbers are estimated from the VMEM/roofline model in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LUT_SIZE = 256 * 256

# Default tile sizes (perf-tuned in EXPERIMENTS.md §Perf; VMEM budget
# per grid step = LUT (256 KiB) + BM*BK + BK*BN + BM*BK*BN gathers + BM*BN
# accumulator, all i32).
BM, BK, BN = 64, 32, 32


def _lut_matmul_kernel(p_ref, w_ref, lut_ref, o_ref):
    """One (BM, BN) output tile, accumulating one (BK,) slice of K."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    p = p_ref[...].astype(jnp.int32)  # [BM, BK]
    w = w_ref[...].astype(jnp.int32)  # [BK, BN]
    lut = lut_ref[...]  # [65536]
    idx = p[:, :, None] * 256 + w[None, :, :]  # [BM, BK, BN]
    prod = jnp.take(lut, idx.reshape(-1), axis=0).reshape(idx.shape)
    o_ref[...] += prod.sum(axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def lut_matmul_pallas(p, w, lut, *, bm: int = BM, bk: int = BK, bn: int = BN):
    """``S[m, n] = sum_k lut[p[m, k] * 256 + w[k, n]]`` via Pallas.

    Shapes must tile evenly: ``M % bm == K % bk == N % bn == 0`` (callers
    pad codes with zeros and weights with zeros; ``lut[0] == 0`` for any
    multiplier whose 0*0 is exact, which holds for every library entry by
    construction of the zero row/column test in the Rust side).
    """
    m, k = p.shape
    k2, n = w.shape
    assert k == k2, (p.shape, w.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _lut_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            # whole LUT resident for every step: constant index map
            pl.BlockSpec((LUT_SIZE,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(p.astype(jnp.int32), w.astype(jnp.int32), lut.astype(jnp.int32))


def pad_to_multiple(x, axis: int, multiple: int, value=0):
    """Pad ``x`` along ``axis`` up to the next multiple (for tile evenness)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x, size
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), size


def lut_matmul(p, w, lut, *, use_pallas: bool, bm: int = BM, bk: int = BK, bn: int = BN):
    """Tile-padding front-end: dispatches to the Pallas kernel or the oracle.

    Padding scheme: P rows pad with code 0, W columns pad with code 0 and
    the shared K axis pads BOTH with code 0, contributing ``lut[0]`` per
    padded k — subtracted exactly afterwards.
    """
    from . import ref

    if not use_pallas:
        return ref.lut_matmul_ref(p.astype(jnp.int32), w.astype(jnp.int32), lut)
    m0, k0 = p.shape
    _, n0 = w.shape
    p_pad, _ = pad_to_multiple(p, 0, bm)
    p_pad, _ = pad_to_multiple(p_pad, 1, bk)
    w_pad, _ = pad_to_multiple(w, 0, bk)
    w_pad, _ = pad_to_multiple(w_pad, 1, bn)
    s = lut_matmul_pallas(p_pad, w_pad, lut, bm=bm, bk=bk, bn=bn)
    s = s[:m0, :n0]
    k_pad = p_pad.shape[1] - k0
    if k_pad:
        # padded K positions contributed lut[0] each
        s = s - lut[0] * k_pad
    return s
