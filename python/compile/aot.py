"""AOT build path: train → fold/quantise → lower to HLO text → export.

Runs ONCE under ``make artifacts``; Python never executes at analysis time.
Outputs in ``--out-dir`` (default ``../artifacts``):

* ``resnet{D}_b{B}.hlo.txt`` — quantised LUT-conv inference graphs
  (runtime inputs: images ``f32[B,16,16,3]``, luts ``i32[L,65536]``; all
  weights/scales are baked constants). HLO *text* interchange — the image's
  xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit ids), see
  /opt/xla-example/README.md.
* ``resnet8_b{B}_pallas.hlo.txt`` — same graph routed through the L1 Pallas
  kernel (interpret-lowered) for the kernel-path artifact + §Perf compare.
* ``resnet{D}.qweights.bin`` — the quantised weights as a flat binary
  (format below) so the pure-Rust native backend can run the identical
  model with no PJRT and no HLO parsing.
* ``test_images.f32`` / ``test_labels.u8`` — the canonical evaluation split.
* ``manifest.json`` — model inventory: per-layer (stage, block, conv,
  n_mults) for the accelerator power model, float/q8 golden accuracies,
  artifact paths (incl. ``qweights``), shapes.

qweights binary format (all little-endian, version 1):

    b"EVOQ" u32(version=1) u32(n_layers)
    per layer: u32 kh kw cin cout stride; f32 s_w; u32 z_w; f32 s_a; u32 z_a;
               u8  w_q[kh*kw*cin*cout]  (row-major [kh,kw,cin,cout]);
               f32 b[cout]
    u32 feat n_classes; f32 dense_w[feat*n_classes]; f32 dense_b[n_classes]
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import train as T


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring).

    ``as_hlo_text(True)`` = print_large_constants: the default printer
    ELIDES big literals as ``constant({...})`` and the text parser then
    silently fabricates values — the baked weight tensors MUST be printed
    in full for the Rust round-trip to be faithful.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_model(qmodel, spec, batch: int, use_pallas: bool) -> str:
    n_layers = len(spec["conv_layers"])
    fn = M.make_inference_fn(qmodel, spec, use_pallas)
    img = jax.ShapeDtypeStruct((batch, D.IMAGE_SIZE, D.IMAGE_SIZE, 3), jnp.float32)
    luts = jax.ShapeDtypeStruct((n_layers, 256 * 256), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(img, luts))


def evaluate_quant(qmodel, spec, data, use_pallas=False, batch=128):
    """Golden-LUT (exact 8-bit multiplier) accuracy of the quantised graph —
    the paper's "8-bit exact" baseline column."""
    images, labels = data
    luts = M.exact_luts(len(spec["conv_layers"]))
    fwd = jax.jit(lambda x: M.forward_quant(qmodel, spec, x, luts, use_pallas))
    correct = 0
    for i in range(0, images.shape[0], batch):
        logits = fwd(jnp.asarray(images[i:i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1)
                               == jnp.asarray(labels[i:i + batch].astype(np.int32))))
    return correct / images.shape[0]


def dump_qweights(qmodel, path: str) -> None:
    """Write the quantised model as the native backend's binary artifact
    (format in the module docstring)."""
    layers = qmodel["layers"]
    with open(path, "wb") as f:
        f.write(b"EVOQ")
        f.write(struct.pack("<II", 1, len(layers)))
        for q in layers:
            kh, kw, cin, cout = q["w_q"].shape
            f.write(struct.pack("<5I", kh, kw, cin, cout, int(q["stride"])))
            f.write(struct.pack("<fIfI",
                                float(q["s_w"]), int(q["z_w"]),
                                float(q["s_a"]), int(q["z_a"])))
            np.asarray(q["w_q"], np.uint8).tofile(f)
            np.asarray(q["b"], "<f4").tofile(f)
        dw = np.asarray(qmodel["dense_w"], "<f4")
        f.write(struct.pack("<II", dw.shape[0], dw.shape[1]))
        dw.tofile(f)
        np.asarray(qmodel["dense_b"], "<f4").tofile(f)


def build(args) -> None:
    os.makedirs(args.out_dir, exist_ok=True)
    depths = [int(d) for d in args.depths.split(",")]
    t_all = time.time()
    print(f"[aot] dataset: train={args.n_train} calib={args.n_calib} "
          f"test={args.n_test}", flush=True)
    train_data, calib_data, test_data = D.canonical_splits(
        args.n_train, args.n_calib, args.n_test)

    # canonical evaluation split for the Rust side
    test_images, test_labels = test_data
    test_images.astype("<f4").tofile(os.path.join(args.out_dir, "test_images.f32"))
    test_labels.astype(np.uint8).tofile(os.path.join(args.out_dir, "test_labels.u8"))

    models = []
    for depth in depths:
        print(f"[aot] training resnet{depth} (width {args.width}, "
              f"≤{args.steps} steps)", flush=True)
        params, state, spec, history = T.train_model(
            depth, args.width, train_data, steps=args.steps,
            batch=args.batch_train, seed=args.seed)
        float_acc = T.evaluate_float(params, state, spec, test_data)
        acts = T.calibration_activations(params, state, spec, calib_data)
        folded, dense = M.fold_bn(params, state, spec)
        qmodel = M.quantize_model(folded, dense, spec, acts)
        q8_acc = evaluate_quant(qmodel, spec, test_data)
        print(f"[aot] resnet{depth}: float acc {float_acc:.4f}, "
              f"8-bit exact acc {q8_acc:.4f}", flush=True)

        entries = [(args.batch, False)]
        if depth == depths[0]:
            entries += [(1, False), (args.batch, True)]
        arts = []
        for batch, use_pallas in entries:
            suffix = "_pallas" if use_pallas else ""
            name = f"resnet{depth}_b{batch}{suffix}.hlo.txt"
            t0 = time.time()
            hlo = lower_model(qmodel, spec, batch, use_pallas)
            with open(os.path.join(args.out_dir, name), "w") as f:
                f.write(hlo)
            print(f"[aot]   wrote {name} ({len(hlo)/1e6:.1f} MB, "
                  f"{time.time()-t0:.1f}s)", flush=True)
            arts.append(dict(path=name, batch=batch,
                             kernel="pallas" if use_pallas else "jnp"))

        qw_name = f"resnet{depth}.qweights.bin"
        dump_qweights(qmodel, os.path.join(args.out_dir, qw_name))
        print(f"[aot]   wrote {qw_name} (native-backend weights)", flush=True)

        counts = M.layer_mult_counts(spec, D.IMAGE_SIZE)
        layers = [
            dict(index=i, stage=c["stage"], block=c["block"], conv=c["conv"],
                 cin=c["cin"], cout=c["cout"], stride=c["stride"],
                 n_mults=counts[i])
            for i, c in enumerate(spec["conv_layers"])
        ]
        models.append(dict(
            name=f"resnet{depth}", depth=depth, width=args.width,
            n_conv_layers=len(spec["conv_layers"]),
            float_acc=float_acc, q8_acc=q8_acc,
            artifacts=arts, layers=layers, qweights=qw_name,
            train_steps=history[-1]["step"] + 1 if history else 0,
        ))

    manifest = dict(
        format="evoapprox-artifacts-v1",
        image=[D.IMAGE_SIZE, D.IMAGE_SIZE, 3],
        n_classes=D.N_CLASSES,
        seed=args.seed,
        testset=dict(images="test_images.f32", labels="test_labels.u8",
                     n=int(test_labels.shape[0])),
        models=models,
    )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] done in {time.time()-t_all:.0f}s — manifest with "
          f"{len(models)} models", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--depths", default=os.environ.get(
        "AOT_DEPTHS", "8,14,20,26,32,38,44,50"))
    ap.add_argument("--width", type=int,
                    default=int(os.environ.get("AOT_WIDTH", "8")))
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("AOT_STEPS", "900")))
    ap.add_argument("--batch", type=int, default=64,
                    help="batch size of the main inference artifacts")
    ap.add_argument("--batch-train", type=int, default=64)
    ap.add_argument("--n-train", type=int,
                    default=int(os.environ.get("AOT_NTRAIN", "4000")))
    ap.add_argument("--n-calib", type=int, default=256)
    ap.add_argument("--n-test", type=int,
                    default=int(os.environ.get("AOT_NTEST", "512")))
    ap.add_argument("--seed", type=int, default=0)
    build(ap.parse_args())


if __name__ == "__main__":
    main()
