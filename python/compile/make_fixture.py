"""Pin the ref.py oracle: generate the golden-logits fixture the Rust
native backend is tested against (``rust/tests/fixtures/native_fixture.json``).

Builds a small untrained-but-calibrated quantised ResNet through the real
production pipeline (init → fold → calibrate → quantise), runs the
``forward_quant``/ref.py path under three LUT configurations, and dumps the
whole quantised model + inputs + expected logits as JSON. Run once and
commit the output; CI then verifies the pure-Rust engine against it with
no Python (or JAX) in the loop:

    python -m compile.make_fixture [--out ../rust/tests/fixtures/native_fixture.json]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from . import train as T

DEPTH = 8
WIDTH = 4
N_IMAGES = 2
TRUNC_KEEP = 6  # mul8u_trunc6 semantics: (a & ~3) * (w & ~3)


def trunc_lut(keep: int) -> np.ndarray:
    mask = 0xFF & ~((1 << (8 - keep)) - 1)
    a = np.arange(256, dtype=np.int32) & mask
    return (a[:, None] * a[None, :]).reshape(-1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "..",
        "rust", "tests", "fixtures", "native_fixture.json"))
    args = ap.parse_args()

    spec = M.resnet_spec(DEPTH, WIDTH)
    params, state = M.init_params(jax.random.PRNGKey(7), spec)
    calib_images, calib_labels = D.make_dataset(64, seed=0xCA11B)
    acts = T.calibration_activations(params, state, spec, (calib_images, calib_labels))
    folded, dense = M.fold_bn(params, state, spec)
    qmodel = M.quantize_model(folded, dense, spec, acts)

    images, _ = D.make_dataset(N_IMAGES, seed=0xF1C5)
    n_layers = len(spec["conv_layers"])
    exact = np.asarray(M.exact_luts(n_layers))
    trunc = np.broadcast_to(trunc_lut(TRUNC_KEEP)[None, :], exact.shape).copy()
    layer2 = exact.copy()
    layer2[2] = trunc_lut(TRUNC_KEEP)

    fwd = jax.jit(lambda x, l: M.forward_quant(qmodel, spec, x, l))
    x = jnp.asarray(images)
    logits = {
        "logits_exact": np.asarray(fwd(x, jnp.asarray(exact))),
        "logits_trunc": np.asarray(fwd(x, jnp.asarray(trunc))),
        "logits_layer2": np.asarray(fwd(x, jnp.asarray(layer2))),
    }

    fixture = dict(
        format="evoapprox-native-fixture-v1",
        depth=DEPTH, width=WIDTH,
        image=[D.IMAGE_SIZE, D.IMAGE_SIZE, D.N_CHANNELS],
        n_classes=D.N_CLASSES,
        trunc_keep=TRUNC_KEEP,
        layers=[
            dict(
                kh=int(q["w_q"].shape[0]), kw=int(q["w_q"].shape[1]),
                cin=int(q["w_q"].shape[2]), cout=int(q["w_q"].shape[3]),
                stride=int(q["stride"]),
                s_w=float(q["s_w"]), z_w=int(q["z_w"]),
                s_a=float(q["s_a"]), z_a=int(q["z_a"]),
                w_q=np.asarray(q["w_q"], np.int32).reshape(-1).tolist(),
                b=[float(v) for v in np.asarray(q["b"], np.float32)],
            )
            for q in qmodel["layers"]
        ],
        dense_w=[float(v) for v in np.asarray(qmodel["dense_w"], np.float32).reshape(-1)],
        dense_b=[float(v) for v in np.asarray(qmodel["dense_b"], np.float32)],
        images=[float(v) for v in np.asarray(images, np.float32).reshape(-1)],
        **{k: [float(x) for x in v.reshape(-1)] for k, v in logits.items()},
    )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(fixture, f)
    print(f"wrote {args.out} "
          f"({os.path.getsize(args.out) / 1024:.0f} KiB, {n_layers} layers)")


if __name__ == "__main__":
    main()
