"""L2 model correctness: architecture counts (paper's 6n+2 family and
ResNet-8 layer census), BN folding, quantised-graph exactness with the
golden LUT, and approximate-LUT degradation direction."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data as D
from compile import model as M


@pytest.fixture(scope="module")
def tiny_setup():
    """A trained-for-a-moment ResNet-8 + data (module-scoped: slow)."""
    from compile import train as T
    train_data = D.make_dataset(256, D.TRAIN_SEED)
    params, state, spec, _ = T.train_model(8, 8, train_data, steps=60,
                                           batch=32, log_every=1000)
    calib = D.make_dataset(64, D.CALIB_SEED)
    acts = T.calibration_activations(params, state, spec, calib)
    folded, dense = M.fold_bn(params, state, spec)
    qmodel = M.quantize_model(folded, dense, spec, acts)
    return params, state, spec, qmodel, calib


def test_depth_family_layer_counts():
    # 6n+2 → 6n+1 conv layers (stem + 3 stages × n blocks × 2)
    for depth in M.SUPPORTED_DEPTHS:
        spec = M.resnet_spec(depth)
        n = (depth - 2) // 6
        assert len(spec["conv_layers"]) == 6 * n + 1
        assert len(spec["blocks"]) == 3 * n


def test_resnet8_matches_paper_census():
    """ResNet-8: 7 conv layers; the paper says the (S=3,R=1,C=1) layer holds
    28.2 % of multipliers and the first layer 2.09 % — our scaled network
    must reproduce the *ordering* (third stage dominant, stem negligible)."""
    spec = M.resnet_spec(8)
    assert len(spec["conv_layers"]) == 7
    counts = M.layer_mult_counts(spec, 16)
    total = sum(counts)
    frac = [c / total for c in counts]
    stem = frac[0]
    s3 = [f for f, c in zip(frac, spec["conv_layers"]) if c["stage"] == 3]
    # (paper: 2.09 % at 32x32/width-16; our scaled 16x16/width-8 geometry
    # raises the stem share slightly but it stays the clear minimum)
    assert stem < 0.10, f"stem fraction {stem:.3f} should be negligible"
    assert stem == min(frac)
    assert max(s3) == max(frac), "a stage-3 conv must carry the peak count"


def test_mult_counts_shrink_with_stride():
    spec = M.resnet_spec(14)
    counts = M.layer_mult_counts(spec, 16)
    assert all(c > 0 for c in counts)
    # channel doubling compensates the spatial/4; deeper stages still touch
    # more total multiplications per layer in this family
    assert counts[-1] >= counts[1]


def test_bn_fold_preserves_inference(tiny_setup):
    params, state, spec, _, calib = tiny_setup
    x = jnp.asarray(calib[0][:8])
    logits_bn, _, _ = M.forward_float(params, state, spec, x, False)
    folded, dense = M.fold_bn(params, state, spec)

    # run the float graph with folded conv+bias, no BN
    def fwd_folded(x):
        h = M._conv_f(x, folded[0]["w"], 1) + folded[0]["b"]
        h = jax.nn.relu(h)
        li = 1
        for blk in spec["blocks"]:
            inp = h
            h = M._conv_f(h, folded[li]["w"], blk["stride"]) + folded[li]["b"]
            h = jax.nn.relu(h)
            li += 1
            h = M._conv_f(h, folded[li]["w"], 1) + folded[li]["b"]
            li += 1
            h = jax.nn.relu(h + M._shortcut_a(inp, blk["stride"], blk["cout"]))
        gap = h.mean(axis=(1, 2))
        return gap @ dense["w"] + dense["b"]

    np.testing.assert_allclose(np.asarray(fwd_folded(x)), np.asarray(logits_bn),
                               rtol=1e-3, atol=1e-3)


def test_quant_graph_close_to_float_with_exact_lut(tiny_setup):
    params, state, spec, qmodel, calib = tiny_setup
    x = jnp.asarray(calib[0][:16])
    y = calib[1][:16].astype(np.int32)
    logits_f, _, _ = M.forward_float(params, state, spec, x, False)
    luts = M.exact_luts(len(spec["conv_layers"]))
    logits_q = M.forward_quant(qmodel, spec, x, luts, use_pallas=False)
    # quantisation noise is bounded; top-1 agreement must be high
    agree = np.mean(np.argmax(np.asarray(logits_f), -1)
                    == np.argmax(np.asarray(logits_q), -1))
    assert agree >= 0.75, f"float/quant top-1 agreement too low: {agree}"
    del y


def test_quant_pallas_equals_quant_jnp(tiny_setup):
    """The Pallas L1 path and the jnp oracle path must agree bit-for-bit on
    logits (same integer accumulators, same float algebra)."""
    _, _, spec, qmodel, calib = tiny_setup
    x = jnp.asarray(calib[0][:4])
    luts = M.exact_luts(len(spec["conv_layers"]))
    a = M.forward_quant(qmodel, spec, x, luts, use_pallas=False)
    b = M.forward_quant(qmodel, spec, x, luts, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_garbage_lut_collapses_accuracy(tiny_setup):
    """An adversarially wrong LUT must push predictions to ~chance — the
    mechanism behind Table II's collapse rows."""
    _, _, spec, qmodel, calib = tiny_setup
    x = jnp.asarray(calib[0][:32])
    n_layers = len(spec["conv_layers"])
    rng = np.random.default_rng(0)
    garbage = jnp.asarray(
        rng.integers(0, 65025, (n_layers, 256 * 256)).astype(np.int32))
    exact = M.forward_quant(qmodel, spec, x, M.exact_luts(n_layers))
    bad = M.forward_quant(qmodel, spec, x, garbage)
    assert not np.allclose(np.asarray(exact), np.asarray(bad))


def test_shortcut_option_a():
    x = jnp.arange(2 * 4 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 4, 3)
    y = M._shortcut_a(x, 2, 8)
    assert y.shape == (2, 2, 2, 8)
    np.testing.assert_array_equal(np.asarray(y[..., 3:]), 0.0)
    np.testing.assert_array_equal(np.asarray(y[..., :3]),
                                  np.asarray(x[:, ::2, ::2, :]))


def test_quant_range_properties():
    s, z = M.quant_range(np.array([-1.0, 2.0]))
    assert s > 0 and 0 <= z <= 255
    codes = M.quantize_codes(np.array([-1.0, 0.0, 2.0]), s, z)
    assert codes.min() >= 0 and codes.max() <= 255
    # zero must be exactly representable
    assert abs((z - z) * s) == 0.0
    s0, z0 = M.quant_range(np.zeros(4))
    assert s0 == 1.0 and z0 == 0
