"""L1 kernel correctness: Pallas LUT-matmul vs the pure-jnp oracle and vs
plain integer arithmetic — the CORE correctness signal of the build path.
Hypothesis sweeps shapes/dtypes per the project brief."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.approx_conv import lut_matmul, lut_matmul_pallas, pad_to_multiple


def random_lut(rng):
    """A plausible approximate-multiplier LUT: exact table + bounded noise."""
    a = np.arange(256, dtype=np.int64)
    exact = (a[:, None] * a[None, :]).reshape(-1)
    noise = rng.integers(-64, 65, exact.shape)
    return jnp.asarray(np.clip(exact + noise, 0, 2**31 - 1).astype(np.int32))


def test_exact_lut_is_multiplication():
    lut = np.asarray(ref.exact_lut())
    for a in [0, 1, 7, 128, 255]:
        for b in [0, 3, 100, 255]:
            assert lut[a * 256 + b] == a * b


def test_ref_matmul_equals_integer_matmul():
    rng = np.random.default_rng(1)
    p = rng.integers(0, 256, (37, 23), dtype=np.int32)
    w = rng.integers(0, 256, (23, 11), dtype=np.int32)
    s = ref.lut_matmul_ref(jnp.asarray(p), jnp.asarray(w), ref.exact_lut())
    np.testing.assert_array_equal(np.asarray(s), p.astype(np.int64) @ w.astype(np.int64))


def test_pallas_matches_ref_exact_tiles():
    rng = np.random.default_rng(2)
    p = jnp.asarray(rng.integers(0, 256, (128, 64), dtype=np.int32))
    w = jnp.asarray(rng.integers(0, 256, (64, 32), dtype=np.int32))
    lut = random_lut(rng)
    s_ref = ref.lut_matmul_ref(p, w, lut)
    s_pal = lut_matmul_pallas(p, w, lut)
    np.testing.assert_array_equal(np.asarray(s_pal), np.asarray(s_ref))


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 90),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref_ragged_shapes(m, k, n, seed):
    """Hypothesis sweep: padding front-end must be exact for any shape."""
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.integers(0, 256, (m, k), dtype=np.int32))
    w = jnp.asarray(rng.integers(0, 256, (k, n), dtype=np.int32))
    lut = random_lut(rng)
    s_ref = ref.lut_matmul_ref(p, w, lut)
    s_pal = lut_matmul(p, w, lut, use_pallas=True, bm=32, bk=16, bn=16)
    np.testing.assert_array_equal(np.asarray(s_pal), np.asarray(s_ref))


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([np.int32, np.uint8, np.int64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_dtype_tolerance(dtype, seed):
    """Codes arriving as other integer dtypes are handled identically."""
    rng = np.random.default_rng(seed)
    p = rng.integers(0, 256, (32, 16)).astype(dtype)
    w = rng.integers(0, 256, (16, 16)).astype(dtype)
    lut = random_lut(rng)
    s_ref = ref.lut_matmul_ref(jnp.asarray(p, jnp.int32), jnp.asarray(w, jnp.int32), lut)
    s_pal = lut_matmul(jnp.asarray(p), jnp.asarray(w), lut,
                       use_pallas=True, bm=16, bk=16, bn=16)
    np.testing.assert_array_equal(np.asarray(s_pal), np.asarray(s_ref))


def test_nonzero_lut0_padding_correction():
    """A LUT with lut[0] != 0 exercises the K-padding correction."""
    rng = np.random.default_rng(3)
    lut = np.asarray(random_lut(rng)).copy()
    lut[0] = 999
    p = jnp.asarray(rng.integers(0, 256, (5, 7), dtype=np.int32))
    w = jnp.asarray(rng.integers(0, 256, (7, 3), dtype=np.int32))
    s_ref = ref.lut_matmul_ref(p, w, jnp.asarray(lut))
    s_pal = lut_matmul(p, w, jnp.asarray(lut), use_pallas=True, bm=8, bk=8, bn=8)
    np.testing.assert_array_equal(np.asarray(s_pal), np.asarray(s_ref))


def test_pad_to_multiple():
    x = jnp.ones((5, 3))
    y, orig = pad_to_multiple(x, 0, 4)
    assert y.shape == (8, 3) and orig == 5
    y2, _ = pad_to_multiple(y, 0, 4)
    assert y2.shape == (8, 3)


def test_im2col_matches_conv():
    """patches @ w == lax.conv for random floats (layout pin)."""
    import jax
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 5)).astype(np.float32))
    for stride in (1, 2):
        patches = ref.im2col(x, 3, 3, stride)
        b, ho, wo, k = patches.shape
        got = (patches.reshape(-1, k) @ w.reshape(k, 5)).reshape(b, ho, wo, 5)
        want = jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_dequantize_acc_exact_roundtrip():
    """With the exact LUT, quant → LUT-matmul → dequant == float matmul of
    the dequantised operands (zero-point algebra exactness)."""
    rng = np.random.default_rng(5)
    s_a, z_a = 0.037, 13
    s_w, z_w = 0.021, 140
    a_codes = rng.integers(0, 256, (17, 29), dtype=np.int32)
    w_codes = rng.integers(0, 256, (29, 9), dtype=np.int32)
    a_real = (a_codes - z_a) * s_a
    w_real = (w_codes - z_w) * s_w
    s = ref.lut_matmul_ref(jnp.asarray(a_codes), jnp.asarray(w_codes), ref.exact_lut())
    a_sum = jnp.asarray(a_codes.sum(axis=1, keepdims=True))
    w_sum = jnp.asarray(w_codes.sum(axis=0, keepdims=True))
    y = ref.dequantize_acc(s, a_sum, w_sum, 29, s_a, z_a, s_w, z_w)
    np.testing.assert_allclose(np.asarray(y), a_real @ w_real, rtol=1e-4, atol=1e-3)
