"""Dataset mirror fidelity: the Python generator must match the Rust
implementation's PRNG stream and produce a learnable, balanced task."""

import numpy as np

from compile import data as D


def test_splitmix_reference_vector():
    # Known-good SplitMix64 outputs for seed 0 (same vector pinned in
    # rust/src/data/rng.rs::splitmix_reference_vector).
    u = D._splitmix_stream(0, 3)
    assert u[0] == 0xE220A8397B1DCDAF
    assert u[1] == 0x6E789E6AA1B965F4
    assert u[2] == 0x06C45D188009454F


def test_deterministic_generation():
    a, la = D.make_dataset(20, seed=7)
    b, lb = D.make_dataset(20, seed=7)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


def test_balanced_and_in_range():
    images, labels = D.make_dataset(100)
    assert images.shape == (100, 16, 16, 3)
    assert images.min() >= 0.0 and images.max() <= 1.0
    counts = np.bincount(labels, minlength=10)
    assert (counts == 10).all()


def test_splits_disjoint_streams():
    (tr, _), (ca, _), (te, _) = D.canonical_splits(10, 10, 10)
    assert not np.array_equal(tr, ca)
    assert not np.array_equal(ca, te)


def test_classes_distinguishable():
    """Nearest-centroid classification on raw pixels must beat chance by a
    wide margin (sanity that the task is learnable)."""
    images, labels = D.make_dataset(400, seed=D.TRAIN_SEED)
    test_images, test_labels = D.make_dataset(100, seed=D.TEST_SEED)
    x = images.reshape(400, -1)
    cents = np.stack([x[labels == c].mean(axis=0) for c in range(10)])
    t = test_images.reshape(100, -1)
    pred = np.argmin(((t[:, None, :] - cents[None]) ** 2).sum(-1), axis=1)
    acc = (pred == test_labels).mean()
    assert acc > 0.5, f"nearest-centroid accuracy only {acc}"
