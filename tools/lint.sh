#!/usr/bin/env bash
# Determinism lints — cheap textual rules that keep the repo's
# byte-identical-output contracts (DESIGN.md §7, §12) from regressing.
#
# Rules:
#   1. No `partial_cmp(..).unwrap()` anywhere under rust/. NaN-poisoned
#      comparators panic at runtime and make sort orders input-dependent;
#      floats must be ordered with `total_cmp` (see cgp/pareto.rs,
#      dse/mod.rs for the idiom).
#   2. No HashMap in modules whose output is contractually deterministic
#      (JSON reports, library serialisation, CGP evolution, DSE). Iteration
#      order of std HashMap is randomised per process; anything that feeds
#      serialised or user-visible output must use BTreeMap or sorted Vecs.
#      Keyed-lookup-only HashMaps are fine elsewhere (cli.rs flag table,
#      store.rs/compiled.rs indexes, server caches) — the module list below is the
#      set where *any* HashMap is one refactor away from leaking ordering
#      into output.
#   3. No same-line iteration of a HashMap (`HashMap ... .iter()/.keys()/
#      .values()/.drain()`) anywhere — catches the declared-and-iterated-
#      in-one-expression case the module allowlist cannot.
#   4. No raw `eprintln!` under rust/src/ outside the logger itself
#      (obs/log.rs) and the bench recorder (util/bench.rs). Diagnostics
#      go through `obs::log` (DESIGN.md §13) so they are leveled,
#      filterable JSON lines stamped with the request id — a stray
#      eprintln! is invisible to `--log-level` and unparseable to log
#      shippers. Comment lines are exempt (docs may name the macro).
#
# Run from the repo root: `bash tools/lint.sh`. Exits non-zero with the
# offending lines on any hit; silent success otherwise.

set -u
cd "$(dirname "$0")/.."

fail=0

hits=$(grep -rn --include='*.rs' 'partial_cmp([^)]*)[[:space:]]*\.[[:space:]]*unwrap()' rust/ || true)
if [ -n "$hits" ]; then
    echo "lint: partial_cmp().unwrap() is non-total and panics on NaN — use total_cmp:" >&2
    echo "$hits" >&2
    fail=1
fi

# modules with a byte-identical-output contract: no HashMap at all
DETERMINISTIC_MODULES="
rust/src/server/report.rs
rust/src/library/entry.rs
rust/src/library/source.rs
rust/src/library/catalog.rs
rust/src/cgp
rust/src/dse
"
for m in $DETERMINISTIC_MODULES; do
    hits=$(grep -rn --include='*.rs' 'HashMap' "$m" 2>/dev/null || true)
    if [ -n "$hits" ]; then
        echo "lint: HashMap in deterministic-output module $m — use BTreeMap or a sorted Vec:" >&2
        echo "$hits" >&2
        fail=1
    fi
done

hits=$(grep -rn --include='*.rs' 'HashMap[^;]*\.\(iter\|keys\|values\|drain\|into_iter\)()' rust/ || true)
if [ -n "$hits" ]; then
    echo "lint: iterating a HashMap — iteration order is process-random; use BTreeMap:" >&2
    echo "$hits" >&2
    fail=1
fi

# library code logs through obs::log, never raw eprintln! (comment lines
# are exempt; the logger and the bench recorder own their stderr writes)
hits=$(grep -rn --include='*.rs' 'eprintln!' rust/src/ \
    | grep -v '^rust/src/obs/log\.rs:' \
    | grep -v '^rust/src/util/bench\.rs:' \
    | grep -v ':[0-9]*:[[:space:]]*//' || true)
if [ -n "$hits" ]; then
    echo "lint: raw eprintln! in rust/src/ — route diagnostics through obs::log:" >&2
    echo "$hits" >&2
    fail=1
fi

if [ "$fail" -ne 0 ]; then
    exit 1
fi
echo "determinism lints: ok"
