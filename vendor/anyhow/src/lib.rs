//! Offline, dependency-free subset of the `anyhow` error-handling crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros and the [`Context`] extension trait
//! for `Result` and `Option`. Semantics mirror the real crate where it
//! matters here:
//!
//! * `{}` prints the outermost message only, `{:#}` prints the whole
//!   context chain joined by `": "` (the format the CLI relies on);
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its `source()` chain;
//! * `context`/`with_context` wrap an error (or a `None`) with an outer
//!   message.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` (that is what makes the blanket `From` impl legal).

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error: `stack[0]` is the outermost (most recent)
/// context, deeper entries are the wrapped causes.
pub struct Error {
    stack: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Error {
        Error {
            stack: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display + Send + Sync + 'static>(mut self, context: C) -> Error {
        self.stack.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.stack.iter().map(String::as_str)
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.stack.first().map(String::as_str).unwrap_or("")
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.stack.iter().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{msg}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut stack = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            stack.push(s.to_string());
            source = s.source();
        }
        Error { stack }
    }
}

/// Extension trait adding `context`/`with_context` to `Result` and
/// `Option` (the subset of the real `Context` trait used here).
pub trait Context<T> {
    /// Wrap the error case with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error case with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds (the subset
/// of the real `ensure!`: a condition plus an optional message).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing thing");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let e = none.context("no value").unwrap_err();
        assert_eq!(format!("{e}"), "no value");
        let name = "x";
        let e = anyhow!("bad flag `{name}`");
        assert_eq!(format!("{e}"), "bad flag `x`");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(format!("{e}"), "1 and 2");
    }

    #[test]
    fn question_mark_conversion() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn bail_early_return() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope: {}", 7);
            }
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "nope: 7");
    }

    #[test]
    fn ensure_early_return() {
        fn inner(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            ensure!(n != 7);
            Ok(n)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(format!("{}", inner(12).unwrap_err()), "n too big: 12");
        assert!(format!("{}", inner(7).unwrap_err()).contains("n != 7"));
    }

    #[test]
    fn error_msg_as_fn_reference() {
        let r: std::result::Result<(), String> = Err("boom".to_string());
        let e = r.map_err(Error::msg).unwrap_err();
        assert_eq!(format!("{e}"), "boom");
    }
}
