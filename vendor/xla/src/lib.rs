//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps the native XLA `xla_extension` shared library,
//! which is not present in this build environment. This stub preserves the
//! exact API surface `evoapproxlib::runtime` compiles against, but every
//! entry point that would need the native runtime returns a descriptive
//! [`Error`] at *runtime* — so the whole analysis/serving stack still
//! builds, tests that need artifacts skip gracefully, and swapping the
//! real bindings back in is a one-line `Cargo.toml` change (see
//! `DESIGN.md` §6).

use std::fmt;

/// Error raised by every stubbed PJRT entry point.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error {
        msg: format!(
            "{what}: XLA/PJRT native runtime is not available in this build \
             (offline `xla` stub; see DESIGN.md §6)"
        ),
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client — always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation — unreachable in the stub (no client exists).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file — always errors in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation built from an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (stub: execution always fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments — always errors in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal — always errors in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal value.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    /// Extract the single element of a 1-tuple.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
