//! Offline subset of the `libc` crate — exactly the readiness-polling
//! surface the evented HTTP server needs (`poll(2)`), nothing else.
//!
//! Vendored per the substitution policy (DESIGN.md §4): the build image
//! has no crates.io access, so external dependencies are replaced by
//! API-compatible shims. Names, layouts and values match the real crate,
//! so swapping the real `libc` back in is a one-line `Cargo.toml` change.
//!
//! `std` deliberately does not expose readiness polling, but `poll(2)` is
//! POSIX and identical on Linux and macOS for the subset here: the
//! `pollfd` layout is fixed by the ABI and the event bits below share the
//! same values on both platforms.

#![allow(non_camel_case_types)]

/// C `int`.
pub type c_int = i32;
/// C `short`.
pub type c_short = i16;

/// Second argument of `poll(2)` (`nfds_t`): `unsigned long` on Linux,
/// `unsigned int` on macOS.
#[cfg(target_os = "macos")]
pub type nfds_t = u32;
/// Second argument of `poll(2)` (`nfds_t`): `unsigned long` on Linux,
/// `unsigned int` on macOS.
#[cfg(not(target_os = "macos"))]
pub type nfds_t = std::os::raw::c_ulong;

/// Readable data (or a pending accept on a listener).
pub const POLLIN: c_short = 0x001;
/// Writable without blocking.
pub const POLLOUT: c_short = 0x004;
/// Error condition (revents only).
pub const POLLERR: c_short = 0x008;
/// Peer hung up (revents only).
pub const POLLHUP: c_short = 0x010;
/// Invalid fd (revents only).
pub const POLLNVAL: c_short = 0x020;

/// One entry of the `poll(2)` interest set — layout fixed by the ABI.
#[repr(C)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct pollfd {
    /// File descriptor (negative entries are ignored by the kernel).
    pub fd: c_int,
    /// Requested events.
    pub events: c_short,
    /// Returned events (written by the kernel).
    pub revents: c_short,
}

extern "C" {
    /// `poll(2)`: block up to `timeout` ms for readiness on `fds`.
    /// Returns the number of ready entries, `0` on timeout, `-1` on error
    /// (with `errno` set — `std::io::Error::last_os_error()` reads it).
    pub fn poll(fds: *mut pollfd, nfds: nfds_t, timeout: c_int) -> c_int;
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    /// Drive the real syscall through the shim: a socket becomes readable
    /// exactly when its peer writes, and a zero timeout reports it as idle
    /// before that.
    #[test]
    fn poll_reports_readability() {
        let (mut a, b) = UnixStream::pair().unwrap();
        let mut fds = [pollfd {
            fd: b.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        }];
        // nothing written yet: an immediate poll times out
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as nfds_t, 0) };
        assert_eq!(n, 0, "socket must be idle before any write");
        a.write_all(b"x").unwrap();
        a.flush().unwrap();
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as nfds_t, 1000) };
        assert_eq!(n, 1, "one fd must be ready");
        assert_ne!(fds[0].revents & POLLIN, 0, "readiness must be POLLIN");
        drop(b);
    }

    /// A hung-up peer surfaces as POLLIN/POLLHUP, never as a silent block.
    #[test]
    fn poll_reports_hangup() {
        let (a, b) = UnixStream::pair().unwrap();
        drop(a);
        let mut fds = [pollfd {
            fd: b.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        }];
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as nfds_t, 1000) };
        assert_eq!(n, 1);
        assert_ne!(
            fds[0].revents & (POLLIN | POLLHUP),
            0,
            "hangup must be observable"
        );
    }
}
